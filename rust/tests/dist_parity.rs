//! Distributed-lease parity suite: a streaming ingest whose level-0
//! reduce batches are leased to remote worker processes must produce
//! **byte-identical** output to the plain in-process run — for any
//! worker count, any `reduce_stages × knn_shards` combination, and
//! under every wire fault the re-lease protocol handles (worker killed
//! mid-lease, torn result frame, connection dropped between frames, no
//! worker ever connecting). The workers here are threads running
//! [`ihtc::dist::serve_with_faults`] in-process over loopback TCP —
//! the same code path `ihtc serve` runs as a separate OS process.
//!
//! The CI `dist` job pins the grid one cell per matrix entry via
//! `IHTC_DIST_WORKERS` / `IHTC_REDUCE_STAGES`; unset (a plain local
//! `cargo test`) every cell runs in one invocation.

use ihtc::checkpoint::FaultPlan;
use ihtc::config::{DataSource, PipelineConfig};
use ihtc::coordinator::driver::{
    ingest_streaming, ingest_streaming_with_pool, StreamedReduction,
};
use ihtc::dist::{serve_with_faults, DistPool, WireFaultPlan};
use std::sync::Arc;
use std::time::Duration;

fn config(n: usize, stages: usize, knn_shards: usize) -> PipelineConfig {
    PipelineConfig {
        source: DataSource::PaperMixture { n },
        streaming: true,
        workers: 2,
        shard_size: 512,
        reduce_stages: stages,
        knn_shards,
        ..Default::default()
    }
}

/// f32 comparisons via to_bits: parity here means *bytes*, not ε.
fn assert_identical(got: &StreamedReduction, base: &StreamedReduction, what: &str) {
    assert_eq!(got.n, base.n, "{what}: n");
    let gb: Vec<u32> = got.prototypes.data().iter().map(|v| v.to_bits()).collect();
    let bb: Vec<u32> = base.prototypes.data().iter().map(|v| v.to_bits()).collect();
    assert_eq!(gb, bb, "{what}: prototype bytes");
    assert_eq!(got.weights, base.weights, "{what}: weights");
    assert_eq!(
        got.level0.read_assignments().unwrap(),
        base.level0.read_assignments().unwrap(),
        "{what}: level-0 assignments"
    );
    assert_eq!(got.labels, base.labels, "{what}: labels");
    assert_eq!(got.moments.count, base.moments.count, "{what}: moments.count");
    let gs: Vec<u64> = got.moments.sum.iter().map(|v| v.to_bits()).collect();
    let bs: Vec<u64> = base.moments.sum.iter().map(|v| v.to_bits()).collect();
    assert_eq!(gs, bs, "{what}: moments.sum bits");
    let gc: Vec<u64> = got.moments.cross.iter().map(|v| v.to_bits()).collect();
    let bc: Vec<u64> = base.moments.cross.iter().map(|v| v.to_bits()).collect();
    assert_eq!(gc, bc, "{what}: moments.cross bits");
}

/// One grid axis: pinned to a single value by the CI matrix env var,
/// the full default sweep otherwise.
fn axis(var: &str, default: &[usize]) -> Vec<usize> {
    match std::env::var(var) {
        Ok(v) => vec![v.parse().unwrap_or_else(|_| panic!("{var} must be an integer, got {v}"))],
        Err(_) => default.to_vec(),
    }
}

/// Start a pool on a free loopback port plus one worker thread per
/// fault plan; waits for them all to be connected.
fn pool_with_workers(
    plans: Vec<WireFaultPlan>,
) -> (Arc<DistPool>, Vec<std::thread::JoinHandle<ihtc::Result<()>>>) {
    let pool = DistPool::listen("127.0.0.1:0", Duration::from_secs(30)).unwrap();
    let n = plans.len();
    let handles: Vec<_> = plans
        .into_iter()
        .map(|plan| {
            let addr = pool.addr().to_string();
            std::thread::spawn(move || serve_with_faults(&addr, 2, &plan))
        })
        .collect();
    assert!(pool.wait_for_workers(n, Duration::from_secs(10)), "workers failed to connect");
    (pool, handles)
}

fn run_with_workers(cfg: &PipelineConfig, plans: Vec<WireFaultPlan>) -> StreamedReduction {
    let (pool, handles) = pool_with_workers(plans);
    let got = ingest_streaming_with_pool(cfg, Some(Arc::clone(&pool)), &FaultPlan::none()).unwrap();
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    got
}

#[test]
fn loopback_grid_matches_in_process() {
    for stages in axis("IHTC_REDUCE_STAGES", &[1, 4]) {
        for knn_shards in [1usize, 4] {
            let cfg = config(2000, stages, knn_shards);
            let base = ingest_streaming(&cfg).unwrap();
            for w in axis("IHTC_DIST_WORKERS", &[1, 2]) {
                let plans = vec![WireFaultPlan::none(); w];
                let got = run_with_workers(&cfg, plans);
                assert_identical(
                    &got,
                    &base,
                    &format!("w{w} stages{stages} knn_shards{knn_shards}"),
                );
            }
        }
    }
}

#[test]
fn killed_worker_mid_lease_falls_back_byte_identically() {
    let stages = axis("IHTC_REDUCE_STAGES", &[4])[0];
    let cfg = config(2000, stages, 2);
    let base = ingest_streaming(&cfg).unwrap();
    // Sole worker vanishes after receiving its first lease: that unit
    // and everything pending abandon, the whole stream reduces locally.
    let got = run_with_workers(
        &cfg,
        vec![WireFaultPlan { kill_after_lease: Some(0), ..WireFaultPlan::none() }],
    );
    assert_identical(&got, &base, "sole worker killed mid-lease");
    // A killer plus a survivor: the dead worker's unit re-leases to the
    // survivor (or abandons in the race where the survivor is also
    // deregistering) — both documented paths, both byte-identical.
    let got = run_with_workers(
        &cfg,
        vec![
            WireFaultPlan { kill_after_lease: Some(0), ..WireFaultPlan::none() },
            WireFaultPlan::none(),
        ],
    );
    assert_identical(&got, &base, "killed worker + survivor");
}

#[test]
fn torn_result_frame_falls_back_byte_identically() {
    let cfg = config(1500, 2, 2);
    let base = ingest_streaming(&cfg).unwrap();
    // The torn frame must read as a dead worker — never as a partial
    // result — and the stream must still complete byte-identically.
    let got = run_with_workers(
        &cfg,
        vec![
            WireFaultPlan { torn_result_at_lease: Some(0), ..WireFaultPlan::none() },
            WireFaultPlan::none(),
        ],
    );
    assert_identical(&got, &base, "torn result frame");
}

#[test]
fn connection_dropped_between_frames_falls_back_byte_identically() {
    let cfg = config(1500, 2, 2);
    let base = ingest_streaming(&cfg).unwrap();
    let got = run_with_workers(
        &cfg,
        vec![
            WireFaultPlan { drop_after_results: Some(1), ..WireFaultPlan::none() },
            WireFaultPlan::none(),
        ],
    );
    assert_identical(&got, &base, "drop between frames");
}

#[test]
fn no_workers_means_plain_in_process_run() {
    let cfg = config(1000, 2, 1);
    let base = ingest_streaming(&cfg).unwrap();
    let pool = DistPool::listen("127.0.0.1:0", Duration::from_secs(5)).unwrap();
    let got = ingest_streaming_with_pool(&cfg, Some(Arc::clone(&pool)), &FaultPlan::none()).unwrap();
    pool.shutdown();
    assert_identical(&got, &base, "no workers connected");
}
