//! Out-of-core streaming integration tests: fused ingest parity with a
//! two-pass shard reduction, end-to-end streaming runs against the
//! materialized baseline, and CSV-sourced streaming.

use ihtc::config::{DataSource, PipelineConfig};
use ihtc::coordinator::driver::{self, ingest_streaming};
use ihtc::coordinator::pipeline::{collect, PipelineBuilder, ReducedShard};
use ihtc::coordinator::PoolKnnProvider;
use ihtc::exec::Executor;
use ihtc::data::synth::gaussian_mixture_paper;
use ihtc::data::{csv, Dataset};
use ihtc::itis::{reduce_shard, ItisConfig, ItisWorkspace, PrototypeKind, StopRule};
use ihtc::rng::Xoshiro256;
use ihtc::Error;

fn streaming_config(n: usize) -> PipelineConfig {
    PipelineConfig {
        source: DataSource::PaperMixture { n },
        streaming: true,
        prototype: PrototypeKind::WeightedCentroid,
        // The executor team; reduce batches are capped by
        // `reduce_stages` independently of this (the cap may exceed the
        // team — extra batches just queue).
        workers: 4,
        shard_size: 700,
        ..Default::default()
    }
}

#[test]
fn fused_prototypes_match_two_pass_run() {
    // The acceptance contract: WeightedCentroid prototypes from the
    // fused single-pass ingest are byte-identical to a two-pass run
    // that materializes each shard separately and reduces it.
    let cfg = streaming_config(5000);
    let stream = ingest_streaming(&cfg).unwrap();
    assert_eq!(stream.n, 5000);

    let ds = gaussian_mixture_paper(5000, cfg.seed);
    let pool = Executor::new(cfg.workers);
    let provider = PoolKnnProvider { exec: &pool, shards: 1 };
    let mut ws = ItisWorkspace::new();
    let itis_cfg = ItisConfig {
        threshold: cfg.threshold,
        stop: StopRule::Iterations(1),
        prototype: PrototypeKind::WeightedCentroid,
        seed_order: cfg.seed_order,
        min_prototypes: 1,
    };
    let mut data: Vec<f32> = Vec::new();
    let mut weights: Vec<u32> = Vec::new();
    let mut start = 0usize;
    while start < 5000 {
        let end = (start + cfg.shard_size).min(5000);
        let shard = ds.points.slice_rows(start, end);
        let red = reduce_shard(&shard, &vec![1; end - start], &itis_cfg, &provider, &pool, &mut ws)
            .unwrap();
        data.extend_from_slice(red.prototypes.data());
        weights.extend_from_slice(&red.weights);
        start = end;
    }
    assert_eq!(stream.prototypes.data(), &data[..]);
    assert_eq!(stream.weights, weights);
    // Every original unit is represented exactly once.
    let total: u64 = stream.weights.iter().map(|&w| w as u64).sum();
    assert_eq!(total, 5000);
    // The fused path held roughly n / t* prototypes, not n rows.
    assert!(stream.prototypes.rows() <= 5000 / cfg.threshold);
}

/// Reduce every shard of the dataset independently (the two-pass
/// materialized reference) into `ReducedShard`s carrying their stream
/// offsets.
fn reference_shards(n: usize, cfg: &PipelineConfig) -> Vec<ReducedShard> {
    let ds = gaussian_mixture_paper(n, cfg.seed);
    let pool = Executor::new(cfg.workers);
    let provider = PoolKnnProvider { exec: &pool, shards: 1 };
    let mut ws = ItisWorkspace::new();
    let itis_cfg = ItisConfig {
        threshold: cfg.threshold,
        stop: StopRule::Iterations(1),
        prototype: PrototypeKind::WeightedCentroid,
        seed_order: cfg.seed_order,
        min_prototypes: 1,
    };
    let mut shards = Vec::new();
    let mut start = 0usize;
    while start < n {
        let end = (start + cfg.shard_size).min(n);
        let shard = ds.points.slice_rows(start, end);
        let red = reduce_shard(&shard, &vec![1; end - start], &itis_cfg, &provider, &pool, &mut ws)
            .unwrap();
        shards.push(ReducedShard {
            offset: start,
            prototypes: red.prototypes,
            weights: red.weights,
            assignments: red.assignments,
            labels: ds.labels.as_ref().map(|l| l[start..end].to_vec()),
        });
        start = end;
    }
    shards
}

/// Concatenate released shards exactly the way the streaming collector
/// does (prototype bytes, weights, offset-rebased assignments).
fn concatenate(shards: &[ReducedShard]) -> (Vec<f32>, Vec<u32>, Vec<u32>) {
    let mut data = Vec::new();
    let mut weights: Vec<u32> = Vec::new();
    let mut assignments = Vec::new();
    for s in shards {
        let base = weights.len() as u32;
        assignments.extend(s.assignments.iter().map(|&a| base + a));
        data.extend_from_slice(s.prototypes.data());
        weights.extend_from_slice(&s.weights);
    }
    (data, weights, assignments)
}

#[test]
fn shuffled_shard_completions_reorder_to_in_order_bytes() {
    // The reorder fan-in property: for any seeded shuffle of shard
    // completion order, the released stream is byte-identical to the
    // in-order single-stage run — prototypes, weights, and back-out
    // assignments all land exactly where the materialized reference puts
    // them.
    let cfg = streaming_config(4000);
    let in_order = reference_shards(4000, &cfg);
    let (want_data, want_weights, want_assignments) = concatenate(&in_order);

    for trial in 1..=4u64 {
        let mut shuffled = in_order.clone();
        Xoshiro256::seed_from_u64(trial).shuffle(&mut shuffled);
        let p = PipelineBuilder::source("completions", 4, move |emit| {
            for s in shuffled {
                emit(s)?;
            }
            Ok(())
        })
        .reorder("reorder", in_order.len() + 2, |s: &ReducedShard| {
            (s.offset, s.assignments.len())
        })
        .build();
        let (released, _) = collect(p).unwrap();
        // Released strictly in stream order…
        let offsets: Vec<usize> = released.iter().map(|s| s.offset).collect();
        assert_eq!(offsets, in_order.iter().map(|s| s.offset).collect::<Vec<_>>(), "trial {trial}");
        // …and the concatenation is the reference bytes.
        let (data, weights, assignments) = concatenate(&released);
        assert_eq!(data, want_data, "trial {trial}");
        assert_eq!(weights, want_weights, "trial {trial}");
        assert_eq!(assignments, want_assignments, "trial {trial}");
    }

    // The real parallel fan-in (N in-flight reduce batches on the
    // shared executor) must agree with the same reference bytes.
    for r in [2usize, 4] {
        let mut cfg = streaming_config(4000);
        cfg.reduce_stages = r;
        let stream = ingest_streaming(&cfg).unwrap();
        assert_eq!(stream.prototypes.data(), &want_data[..], "reduce_stages={r}");
        assert_eq!(stream.weights, want_weights, "reduce_stages={r}");
        assert_eq!(
            stream.level0.read_assignments().unwrap(),
            want_assignments,
            "reduce_stages={r}"
        );
    }
}

#[test]
fn gapped_shard_stream_is_root_cause_through_join() {
    // Drop one mid-stream shard: the reorder stage must fail the whole
    // pipeline with the gap as the root cause (a hard error in release
    // builds, not a debug_assert).
    let cfg = streaming_config(3000);
    let mut shards = reference_shards(3000, &cfg);
    shards.remove(2);
    let p = PipelineBuilder::source("completions", 4, move |emit| {
        for s in shards {
            emit(s)?;
        }
        Ok(())
    })
    .reorder("reorder", 16, |s: &ReducedShard| (s.offset, s.assignments.len()))
    .build();
    let err = collect(p).unwrap_err();
    assert!(matches!(err, Error::Coordinator(_)), "{err}");
    assert!(err.to_string().contains("gap"), "{err}");
}

#[test]
fn duplicate_shard_offset_is_root_cause_through_join() {
    let cfg = streaming_config(3000);
    let mut shards = reference_shards(3000, &cfg);
    let dup = shards[1].clone();
    shards.push(dup);
    let p = PipelineBuilder::source("completions", 4, move |emit| {
        for s in shards {
            emit(s)?;
        }
        Ok(())
    })
    .reorder("reorder", 16, |s: &ReducedShard| (s.offset, s.assignments.len()))
    .build();
    let err = collect(p).unwrap_err();
    assert!(matches!(err, Error::Coordinator(_)), "{err}");
    let msg = err.to_string();
    assert!(msg.contains("duplicate") || msg.contains("overlap") || msg.contains("released"),
        "{msg}");
}

#[test]
fn streaming_accuracy_matches_materialized_band() {
    // Shard-wise level-0 TC is a different (but equally valid) reduction
    // from global TC — accuracy must stay in the same band.
    let n = 8000;
    let mut materialized = streaming_config(n);
    materialized.streaming = false;
    let (_, base) = driver::run(&materialized).unwrap();
    let (assign, report) = driver::run(&streaming_config(n)).unwrap();
    assert_eq!(assign.len(), n);
    let base_acc = base.accuracy.unwrap();
    let stream_acc = report.accuracy.unwrap();
    assert!(
        stream_acc > base_acc - 0.05,
        "streaming accuracy dropped: {base_acc} → {stream_acc}"
    );
    // Both reduced by ≥ (t*)² over two iterations.
    assert!(report.prototypes <= n / 4 + 16);
}

#[test]
fn streaming_from_csv_source() {
    // Round-trip: synthetic data → CSV on disk → chunked streaming run.
    let ds = gaussian_mixture_paper(2500, 77);
    let dir = std::env::temp_dir().join("ihtc_streaming_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("stream_src.csv");
    csv::write_csv(&ds, &path).unwrap();

    let mut cfg = streaming_config(0);
    cfg.source = DataSource::Csv {
        path: path.to_string_lossy().into_owned(),
        label_column: Some(2),
    };
    cfg.shard_size = 600;
    let out = dir.join("stream_out.csv");
    cfg.output = Some(out.to_string_lossy().into_owned());
    let (assign, report) = driver::run(&cfg).unwrap();
    assert_eq!(assign.len(), 2500);
    assert_eq!(report.n, 2500);
    assert!(report.accuracy.is_some());
    assert!(report.accuracy.unwrap() > 0.80, "{report:?}");
    let text = std::fs::read_to_string(&out).unwrap();
    assert_eq!(text.lines().count(), 2501);
}

#[test]
fn streaming_csv_without_labels_reports_no_accuracy() {
    let ds = gaussian_mixture_paper(900, 78);
    let unlabeled = Dataset::new("u", ds.points.clone(), None, 3).unwrap();
    let dir = std::env::temp_dir().join("ihtc_streaming_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("stream_nolabel.csv");
    csv::write_csv(&unlabeled, &path).unwrap();
    let mut cfg = streaming_config(0);
    cfg.source = DataSource::Csv { path: path.to_string_lossy().into_owned(), label_column: None };
    cfg.shard_size = 256;
    let (assign, report) = driver::run(&cfg).unwrap();
    assert_eq!(assign.len(), 900);
    assert!(report.accuracy.is_none());
    assert!(report.bss_tss > 0.0);
}
