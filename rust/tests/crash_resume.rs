//! Crash/recovery acceptance suite for the checkpointed streaming
//! ingest: an interrupted-then-resumed run must be byte-identical to an
//! uninterrupted one — prototypes, weights, level-0 assignments, labels,
//! and (f64-exact) moments — for crash points at shard boundaries and
//! mid-shard, across the `reduce_stages × knn_shards` grid, and a torn
//! or corrupted checkpoint tail must be detected and truncated to the
//! last valid frame, never silently consumed.

use ihtc::checkpoint::{self, FaultPlan};
use ihtc::config::{DataSource, PipelineConfig};
use ihtc::coordinator::driver::{
    ingest_streaming, ingest_streaming_with_faults, run, StreamedReduction,
};
use ihtc::itis::PrototypeKind;
use ihtc::Error;
use std::io::Write;
use std::path::PathBuf;

/// Fresh checkpoint destination under a per-suite temp dir: removes any
/// stale dest/tmp pair from a previous test-binary invocation so every
/// run starts from a clean slate.
fn fresh_ckpt(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ihtc_crash_resume");
    std::fs::create_dir_all(&dir).unwrap();
    let dest = dir.join(format!("{name}.ckpt"));
    let _ = std::fs::remove_file(&dest);
    let _ = std::fs::remove_file(checkpoint::tmp_path(&dest));
    dest
}

/// Streaming config over the paper mixture. `ckpt: None` is the
/// uninterrupted reference (anonymous spill only); `Some` arms the
/// durable checkpoint with `resume: true`, which is a no-op on the
/// first run (no file yet) and a replay on every later one.
fn config(n: usize, stages: usize, knn_shards: usize, ckpt: Option<&PathBuf>) -> PipelineConfig {
    PipelineConfig {
        source: DataSource::PaperMixture { n },
        streaming: true,
        prototype: PrototypeKind::WeightedCentroid,
        workers: 4,
        shard_size: 512,
        reduce_stages: stages,
        knn_shards,
        checkpoint_path: ckpt.map(|p| p.to_string_lossy().into_owned()),
        resume: ckpt.is_some(),
        ..Default::default()
    }
}

fn assert_identical(got: &StreamedReduction, base: &StreamedReduction, what: &str) {
    assert_eq!(got.n, base.n, "{what}: n");
    let gb: Vec<u32> = got.prototypes.data().iter().map(|v| v.to_bits()).collect();
    let bb: Vec<u32> = base.prototypes.data().iter().map(|v| v.to_bits()).collect();
    assert_eq!(gb, bb, "{what}: prototype bytes");
    assert_eq!(got.weights, base.weights, "{what}: weights");
    assert_eq!(
        got.level0.read_assignments().unwrap(),
        base.level0.read_assignments().unwrap(),
        "{what}: level-0 assignments"
    );
    assert_eq!(got.labels, base.labels, "{what}: labels");
    assert_eq!(got.moments.count, base.moments.count, "{what}: moment count");
    let gs: Vec<u64> = got.moments.sum.iter().map(|v| v.to_bits()).collect();
    let bs: Vec<u64> = base.moments.sum.iter().map(|v| v.to_bits()).collect();
    assert_eq!(gs, bs, "{what}: moment sums");
    let gc: Vec<u64> = got.moments.cross.iter().map(|v| v.to_bits()).collect();
    let bc: Vec<u64> = base.moments.cross.iter().map(|v| v.to_bits()).collect();
    assert_eq!(gc, bc, "{what}: moment cross");
}

/// Interrupt a run by failing the source at `row`, then resume it.
/// Returns the resumed reduction. The interruption must surface as the
/// injected root cause, never a "hung up" symptom.
fn interrupt_and_resume(cfg: &PipelineConfig, row: usize, what: &str) -> StreamedReduction {
    let faults = FaultPlan { fail_source_at_row: Some(row), ..FaultPlan::none() };
    let err = ingest_streaming_with_faults(cfg, &faults).unwrap_err();
    assert!(err.to_string().contains("fault injection"), "{what}: {err}");
    ingest_streaming(cfg).unwrap()
}

#[test]
fn kill_and_resume_byte_identical_across_grid() {
    // The acceptance grid: crash at a shard boundary (row 1536 = 3 ×
    // shard_size) and mid-shard (row 1800), across reduce_stages ×
    // knn_shards. n = 2600 ends on a partial shard (40 rows) so the
    // resumed tail also re-creates the short final shard.
    let n = 2600;
    let base = ingest_streaming(&config(n, 1, 1, None)).unwrap();
    assert_eq!(base.n, n);
    for stages in [1usize, 2, 4] {
        for knn_shards in [1usize, 4] {
            for crash_row in [1536usize, 1800] {
                let what = format!("stages={stages} knn={knn_shards} crash={crash_row}");
                let ckpt = fresh_ckpt(&format!("grid_{stages}_{knn_shards}_{crash_row}"));
                let cfg = config(n, stages, knn_shards, Some(&ckpt));
                let resumed = interrupt_and_resume(&cfg, crash_row, &what);
                assert_identical(&resumed, &base, &what);
                // The completed run committed the checkpoint into place.
                assert!(ckpt.exists(), "{what}: no committed checkpoint");
            }
        }
    }
}

#[test]
fn reduce_stage_kill_mid_shard_is_resumable() {
    // Kill a reduce batch (panic, not a clean Err) while it holds the
    // shard at offset 1024: the executor converts the worker panic into
    // a coordinator error, join surfaces it as the root cause, the
    // checkpoint keeps its offset-tiled prefix, and the resumed run is
    // byte-identical to the uninterrupted one.
    let n = 2600;
    let base = ingest_streaming(&config(n, 1, 1, None)).unwrap();
    let ckpt = fresh_ckpt("stage_kill");
    let cfg = config(n, 2, 1, Some(&ckpt));
    let faults = FaultPlan { kill_reduce_at_offset: Some(1024), ..FaultPlan::none() };
    let err = ingest_streaming_with_faults(&cfg, &faults).unwrap_err();
    assert!(matches!(err, Error::Coordinator(_)), "{err}");
    assert!(err.to_string().contains("panicked"), "{err}");
    let resumed = ingest_streaming(&cfg).unwrap();
    assert_identical(&resumed, &base, "stage kill");
}

#[test]
fn kill_and_resume_with_more_batches_than_workers() {
    // Executor-native grid point: `reduce_stages` is an in-flight batch
    // cap, so 8 in-flight batches on a 4-worker team (queue pressure the
    // retired per-stage threads could never create) must still crash and
    // resume byte-identically — here at a bulk priority, so the reduce
    // batches also sit in the lowest-priority queue behind nothing.
    let n = 2600;
    let base = ingest_streaming(&config(n, 1, 1, None)).unwrap();
    let ckpt = fresh_ckpt("wide_batch_kill");
    let mut cfg = config(n, 8, 1, Some(&ckpt));
    cfg.reduce_priority = ihtc::exec::Priority::Bulk;
    cfg.validate().unwrap();
    let faults = FaultPlan { kill_reduce_at_offset: Some(1536), ..FaultPlan::none() };
    let err = ingest_streaming_with_faults(&cfg, &faults).unwrap_err();
    assert!(matches!(err, Error::Coordinator(_)), "{err}");
    assert!(err.to_string().contains("panicked"), "{err}");
    let resumed = ingest_streaming(&cfg).unwrap();
    assert_identical(&resumed, &base, "wide batch kill");
}

#[test]
fn sink_write_error_aborts_with_coordinator_error() {
    // A checkpoint-sink write failure must abort the whole run with
    // Error::Coordinator as the root cause (not a hang-up symptom), and
    // the frames written before the failure must still support resume.
    let n = 2600;
    let base = ingest_streaming(&config(n, 1, 1, None)).unwrap();
    let ckpt = fresh_ckpt("sink_fail");
    let cfg = config(n, 2, 1, Some(&ckpt));
    let faults = FaultPlan { fail_sink_at_frame: Some(2), ..FaultPlan::none() };
    let err = ingest_streaming_with_faults(&cfg, &faults).unwrap_err();
    assert!(matches!(err, Error::Coordinator(_)), "{err}");
    assert!(err.to_string().contains("checkpoint sink"), "{err}");
    let resumed = ingest_streaming(&cfg).unwrap();
    assert_identical(&resumed, &base, "sink failure");
}

#[test]
fn torn_or_corrupted_tail_truncates_to_last_valid_frame() {
    // Tamper with the interrupted run's tmp file the way a real crash
    // would: garbage appended past the last frame, a short (torn) final
    // frame, and a bit flip inside the final frame's payload. All three
    // must be detected and truncated to the last CRC-clean frame, and
    // the resumed run must still be byte-identical.
    let n = 2600;
    let base = ingest_streaming(&config(n, 1, 1, None)).unwrap();
    for (tamper, name) in [
        (0u8, "tail_garbage"),
        (1u8, "tail_torn"),
        (2u8, "tail_bitflip"),
    ] {
        let ckpt = fresh_ckpt(name);
        let cfg = config(n, 1, 1, Some(&ckpt));
        let faults = FaultPlan { fail_source_at_row: Some(1800), ..FaultPlan::none() };
        ingest_streaming_with_faults(&cfg, &faults).unwrap_err();
        let tmp = checkpoint::tmp_path(&ckpt);
        assert!(tmp.exists(), "{name}: interrupted run left no tmp checkpoint");
        match tamper {
            0 => {
                // Garbage past the last frame boundary.
                let mut f = std::fs::OpenOptions::new().append(true).open(&tmp).unwrap();
                f.write_all(&[0xAB; 16]).unwrap();
            }
            1 => {
                // Torn final frame: chop bytes off the end.
                let len = std::fs::metadata(&tmp).unwrap().len();
                let f = std::fs::OpenOptions::new().write(true).open(&tmp).unwrap();
                f.set_len(len - 5).unwrap();
            }
            _ => {
                // Bit flip inside the final frame: CRC must catch it.
                let mut bytes = std::fs::read(&tmp).unwrap();
                let at = bytes.len() - 10;
                bytes[at] ^= 0x40;
                std::fs::write(&tmp, &bytes).unwrap();
            }
        }
        let resumed = ingest_streaming(&cfg).unwrap();
        assert_identical(&resumed, &base, name);
    }
}

#[test]
fn foreign_file_at_checkpoint_path_is_a_hard_error() {
    // A file that is not a checkpoint (wrong magic) must never be
    // truncated or overwritten by resume — that would destroy user data
    // on a mistyped path.
    let ckpt = fresh_ckpt("foreign");
    std::fs::write(checkpoint::tmp_path(&ckpt), b"definitely not a checkpoint file").unwrap();
    let cfg = config(2600, 1, 1, Some(&ckpt));
    let err = ingest_streaming(&cfg).unwrap_err();
    assert!(err.to_string().contains("magic"), "{err}");
}

#[test]
fn resume_of_a_completed_run_is_idempotent() {
    // Resuming a checkpoint that already covers the whole stream must
    // replay it without touching the source (zero new frames) and
    // return the same bytes again.
    let n = 2600;
    let ckpt = fresh_ckpt("completed");
    let cfg = config(n, 1, 1, Some(&ckpt));
    let first = ingest_streaming(&cfg).unwrap();
    assert!(ckpt.exists());
    let again = ingest_streaming(&cfg).unwrap();
    assert_identical(&again, &first, "completed-run resume");
}

#[test]
fn full_run_after_interrupted_ingest_matches_uninterrupted() {
    // End-to-end: interrupt the checkpointed ingest, then drive the
    // whole pipeline (remaining ITIS iterations, clusterer, back-out)
    // through `run` with resume — the final per-unit labels must equal
    // an uninterrupted run's.
    let n = 2600;
    let (want, _) = run(&config(n, 1, 1, None)).unwrap();
    let ckpt = fresh_ckpt("full_run");
    let cfg = config(n, 2, 1, Some(&ckpt));
    let faults = FaultPlan { fail_source_at_row: Some(1536), ..FaultPlan::none() };
    ingest_streaming_with_faults(&cfg, &faults).unwrap_err();
    let (got, report) = run(&cfg).unwrap();
    assert_eq!(got, want);
    assert_eq!(report.n, n);
    assert_eq!(report.iterations, 2);
}

#[test]
fn csv_source_resume_is_byte_identical() {
    // The CSV arm of the resume contract: seek_to_row must land the
    // reader exactly where the checkpoint stops, labels included.
    let n = 2000;
    let ds = ihtc::data::synth::gaussian_mixture_paper(n, 77);
    let dir = std::env::temp_dir().join("ihtc_crash_resume");
    std::fs::create_dir_all(&dir).unwrap();
    let csv_path = dir.join("resume_source.csv");
    let mut w = std::io::BufWriter::new(std::fs::File::create(&csv_path).unwrap());
    writeln!(w, "x,y,label").unwrap();
    let labels = ds.labels.as_ref().unwrap();
    for i in 0..n {
        let row = ds.points.row(i);
        writeln!(w, "{},{},{}", row[0], row[1], labels[i]).unwrap();
    }
    w.flush().unwrap();
    drop(w);
    let source = DataSource::Csv {
        path: csv_path.to_string_lossy().into_owned(),
        label_column: Some(2),
    };
    let mut base_cfg = config(n, 1, 1, None);
    base_cfg.source = source.clone();
    let base = ingest_streaming(&base_cfg).unwrap();
    assert_eq!(base.n, n);
    let ckpt = fresh_ckpt("csv_resume");
    let mut cfg = config(n, 2, 1, Some(&ckpt));
    cfg.source = source;
    let resumed = interrupt_and_resume(&cfg, 1000, "csv resume");
    assert_identical(&resumed, &base, "csv resume");
}
