//! Kernel and bounds parity suite — the executable form of the
//! FP-ordering contract in `linalg::simd`:
//!
//! * **Scalar vs dispatched kernel.** With the `simd` feature off or
//!   `IHTC_FORCE_SCALAR` set, the dispatched kernels *are* the scalar
//!   kernels and every comparison here is `to_bits` equality. With the
//!   AVX2/FMA kernels active, the reduction is reassociated, so the
//!   contract weakens to bounded relative error: for squared distance
//!   and dot, `|simd − scalar| ≤ 1e-5 · (1 + |scalar|)` across every
//!   dimension and input scale tested. Dimensions below
//!   `SIMD_MIN_DIM` never enter the vector body and stay `to_bits`
//!   equal under every dispatch.
//! * **Norm-trick vs direct.** The chunked k-NN path computes
//!   `‖q‖² + ‖r‖² − 2·q·r` instead of the direct subtract-square sum.
//!   That identity cancels catastrophically when `‖q − r‖² ≪ ‖q‖²`,
//!   so its contract is *absolute* in the input scale:
//!   `|trick − direct| ≤ 1e-4 · (1 + ‖q‖² + ‖r‖²)`. This is the same
//!   bound the existing k-NN equivalence tests rely on implicitly;
//!   here it is pinned per kernel so a kernel change that breaks it
//!   fails fast with the dimension in the message.
//! * **Bounded vs unbounded k-means.** Elkan/Hamerly pruning is not a
//!   tolerance contract at all: assignments, WCSS, centers, and
//!   iteration counts must be `to_bits`-identical for every worker
//!   count, because the pruned scans are provably non-winners and
//!   every computed value is untouched.
//!
//! CI's `kernels` job runs this file (with the whole suite) three
//! times: `--features simd`, `--features simd` + `IHTC_FORCE_SCALAR=1`,
//! and featureless — so both branches of every `if simd::active()`
//! below are exercised on every push.

use ihtc::cluster::kmeans::{kmeans_pool, KMeansConfig, KMeansWorkspace, NativeAssign};
use ihtc::data::synth::gaussian_mixture_paper;
use ihtc::exec::Executor;
use ihtc::linalg::{dot_scalar, simd, sq_dist_scalar, sq_norm, Matrix, SIMD_MIN_DIM};

/// The dims the contract is pinned at: both sides of `SIMD_MIN_DIM`,
/// the exact threshold, a non-multiple of the 8-lane width, and two
/// multi-lane sizes.
const DIMS: [usize; 7] = [1, 2, 4, 7, 8, 33, 64];

/// Deterministic pseudo-random vector (LCG — no rand dependency).
fn lcg_vec(n: usize, salt: u32, scale: f32) -> Vec<f32> {
    let mut state = 0x9e37_79b9u32 ^ salt;
    (0..n)
        .map(|_| {
            state = state.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
            ((state >> 8) as f32 / (1u32 << 24) as f32 - 0.5) * scale
        })
        .collect()
}

#[test]
fn dispatched_kernels_match_scalar_per_contract() {
    for &d in &DIMS {
        for (pair, scale) in [(0u32, 1.0f32), (1, 8.0), (2, 0.05)] {
            let a = lcg_vec(d, pair * 2 + 1, scale);
            let b = lcg_vec(d, pair * 2 + 2, scale);
            let (sq_ref, dot_ref) = (sq_dist_scalar(&a, &b), dot_scalar(&a, &b));
            let sq = (simd::sq_dist_kernel())(&a, &b);
            let dot = (simd::dot_kernel())(&a, &b);
            if simd::active() && d >= SIMD_MIN_DIM {
                assert!(
                    (sq - sq_ref).abs() <= 1e-5 * (1.0 + sq_ref.abs()),
                    "sq_dist d={d} scale={scale}: {sq} vs {sq_ref}"
                );
                assert!(
                    (dot - dot_ref).abs() <= 1e-5 * (1.0 + dot_ref.abs()),
                    "dot d={d} scale={scale}: {dot} vs {dot_ref}"
                );
            } else {
                // Scalar dispatch (feature off / forced / CPU fallback)
                // and the sub-threshold dims are byte-contracts.
                assert_eq!(sq.to_bits(), sq_ref.to_bits(), "sq_dist d={d} scale={scale}");
                assert_eq!(dot.to_bits(), dot_ref.to_bits(), "dot d={d} scale={scale}");
            }
        }
    }
}

#[test]
fn public_sq_dist_is_the_dispatched_kernel() {
    // `linalg::sq_dist` must route through the same dispatch decision
    // as the hoisted kernel pointers — a drift here would mean hot
    // loops and one-off call sites disagree about distances.
    for &d in &DIMS {
        let a = lcg_vec(d, 71, 2.0);
        let b = lcg_vec(d, 72, 2.0);
        assert_eq!(
            ihtc::linalg::sq_dist(&a, &b).to_bits(),
            (simd::sq_dist_kernel())(&a, &b).to_bits(),
            "d={d}"
        );
    }
}

#[test]
fn norm_trick_matches_direct_within_absolute_contract() {
    for &d in &DIMS {
        for (pair, scale) in [(0u32, 1.0f32), (1, 16.0)] {
            let a = lcg_vec(d, pair * 2 + 11, scale);
            // Include a near-duplicate pair: worst case for the
            // cancellation in ‖q‖² + ‖r‖² − 2·q·r.
            for b in [lcg_vec(d, pair * 2 + 12, scale), {
                let mut b = a.clone();
                if let Some(x) = b.first_mut() {
                    *x += 1e-3;
                }
                b
            }] {
                let direct = (simd::sq_dist_kernel())(&a, &b);
                let dot = (simd::dot_kernel())(&a, &b);
                let trick = (sq_norm(&a) + sq_norm(&b) - 2.0 * dot).max(0.0);
                let budget = 1e-4 * (1.0 + sq_norm(&a) + sq_norm(&b));
                assert!(
                    (trick - direct).abs() <= budget,
                    "norm trick d={d} scale={scale}: {trick} vs {direct} (budget {budget})"
                );
            }
        }
    }
}

#[test]
fn bounded_kmeans_byte_identical_for_every_worker_count() {
    // n ≥ 2·PART (8192) so worker counts > 1 actually take the pooled
    // path; w=1 exercises the serial fallback inside kmeans_pool.
    let ds = gaussian_mixture_paper(17_000, 417);
    let base = KMeansConfig { restarts: 2, ..KMeansConfig::new(3) };
    let mut reference: Option<(Vec<u32>, u64, Vec<u32>, usize)> = None;
    for workers in [1usize, 2, 4] {
        let exec = Executor::new(workers);
        let mut ws = KMeansWorkspace::new();
        let off = kmeans_pool(&ds.points, None, &base, &NativeAssign, &exec, &mut ws).unwrap();
        let mut ws_b = KMeansWorkspace::new();
        let on = kmeans_pool(
            &ds.points,
            None,
            &KMeansConfig { bounds: true, ..base },
            &NativeAssign,
            &exec,
            &mut ws_b,
        )
        .unwrap();
        // Bounds on vs off: byte-identical at this worker count.
        assert_eq!(off.assignments, on.assignments, "w={workers}");
        assert_eq!(off.wcss.to_bits(), on.wcss.to_bits(), "w={workers}");
        assert_eq!(off.iterations, on.iterations, "w={workers}");
        let cb: Vec<u32> = on.centers.data().iter().map(|v| v.to_bits()).collect();
        let cb_off: Vec<u32> = off.centers.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(cb_off, cb, "w={workers}");
        assert_eq!(off.bound_checks, 0, "unbounded run must not count bound checks");
        // …and the pruning must actually fire on separated blobs, at
        // every worker count (a 0% hit rate would mean the bounds are
        // dead weight, not merely conservative).
        assert!(on.bound_hits > 0, "w={workers}: no bound ever pruned");
        assert!(on.bound_hits <= on.bound_checks, "w={workers}");
        // Pooled (w>1) vs serial (w=1): the pooled path reassociates
        // partial sums at fixed part boundaries, identically for every
        // worker count — so all pooled runs must agree with each other.
        if workers == 1 {
            continue;
        }
        match &reference {
            None => reference = Some((on.assignments, on.wcss.to_bits(), cb, on.iterations)),
            Some((ra, rw, rc, ri)) => {
                assert_eq!(*ra, on.assignments, "pooled runs disagree at w={workers}");
                assert_eq!(*rw, on.wcss.to_bits(), "pooled runs disagree at w={workers}");
                assert_eq!(*rc, cb, "pooled runs disagree at w={workers}");
                assert_eq!(*ri, on.iterations, "pooled runs disagree at w={workers}");
            }
        }
    }
}

#[test]
fn bounded_kmeans_survives_all_duplicate_points() {
    // Every distance is 0 and every center collapses onto the single
    // point: bounds must neither prune incorrectly nor diverge from
    // the unbounded path on fully degenerate input.
    let points = Matrix::from_vec(vec![1.25f32; 200 * 2], 200, 2).unwrap();
    let cfg = KMeansConfig::new(3);
    let exec = Executor::new(2);
    let mut ws = KMeansWorkspace::new();
    let off = kmeans_pool(&points, None, &cfg, &NativeAssign, &exec, &mut ws).unwrap();
    let mut ws_b = KMeansWorkspace::new();
    let on = kmeans_pool(
        &points,
        None,
        &KMeansConfig { bounds: true, ..cfg },
        &NativeAssign,
        &exec,
        &mut ws_b,
    )
    .unwrap();
    assert_eq!(off.assignments, on.assignments);
    assert_eq!(off.wcss.to_bits(), on.wcss.to_bits());
    assert_eq!(off.iterations, on.iterations);
}
