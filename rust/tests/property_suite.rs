//! Randomized cross-stack property suite (hand-rolled; proptest is not
//! available offline). Each case draws a workload from a seeded PRNG and
//! checks the system invariants the paper's guarantees rest on.

use ihtc::cluster::hac::{hac_cut, HacConfig, Linkage};
use ihtc::data::synth::{paper_mixture_spec, Component, MixtureSpec};
use ihtc::hybrid::{FinalClusterer, Ihtc};
use ihtc::itis::{itis, ItisConfig};
use ihtc::knn::graph::NeighborGraph;
use ihtc::knn::{knn_auto, knn_brute};
use ihtc::linalg::Matrix;
use ihtc::metrics;
use ihtc::rng::Xoshiro256;
use ihtc::tc::{threshold_cluster_graph, validate, TcConfig};

/// Random mixture with 1–5 components in 1–6 dimensions.
fn random_mixture(rng: &mut Xoshiro256) -> MixtureSpec {
    let k = 1 + rng.next_below(5) as usize;
    let d = 1 + rng.next_below(6) as usize;
    let components = (0..k)
        .map(|_| Component {
            weight: 0.2 + rng.next_f64(),
            mean: (0..d).map(|_| rng.next_gaussian() * 8.0).collect(),
            std: (0..d).map(|_| 0.2 + rng.next_f64() * 2.0).collect(),
            corr: if rng.next_below(3) == 0 { 0.5 } else { 0.0 },
            skew: rng.next_below(4) == 0,
        })
        .collect();
    MixtureSpec { name: "prop".into(), components, noise_frac: rng.next_f64() * 0.05 }
}

#[test]
fn tc_invariants_hold_on_random_workloads() {
    let mut rng = Xoshiro256::seed_from_u64(0xF00D);
    for case in 0..30 {
        let spec = random_mixture(&mut rng);
        let n = 40 + rng.next_below(500) as usize;
        let t = 2 + rng.next_below(6) as usize;
        let ds = spec.sample(n, 5000 + case);
        if n <= t {
            continue;
        }
        let knn = knn_auto(&ds.points, t - 1).unwrap();
        let g = NeighborGraph::from_knn(&knn);
        let r = threshold_cluster_graph(&g, &ds.points, &TcConfig::new(t));
        validate(&r, &g, t).unwrap_or_else(|e| panic!("case {case}: {e}"));
        // 4λ bound via the max graph edge (a lower bound on λ).
        let bound = 4.0 * (g.max_weight() as f64).sqrt();
        let got = metrics::bottleneck(&ds.points, &r.assignments, usize::MAX).unwrap();
        assert!(got <= bound + 1e-5, "case {case}: {got} > {bound}");
    }
}

#[test]
fn itis_composition_and_mass_conservation() {
    let mut rng = Xoshiro256::seed_from_u64(0xBEEF);
    for case in 0..20 {
        let spec = random_mixture(&mut rng);
        let n = 60 + rng.next_below(800) as usize;
        let t = 2 + rng.next_below(3) as usize;
        let m = 1 + rng.next_below(3) as usize;
        let ds = spec.sample(n, 6000 + case);
        let r = itis(&ds.points, &ItisConfig::iterations(t, m)).unwrap();
        // Mass conservation.
        assert_eq!(r.weights.iter().map(|&w| w as u64).sum::<u64>(), n as u64);
        // Composition consistency.
        let map = r.unit_to_prototype();
        let np = r.prototypes.rows();
        assert!(map.iter().all(|&p| (p as usize) < np));
        // Reduction guarantee per performed iteration.
        assert!(np <= n / t.pow(r.iterations() as u32).max(1) || r.iterations() == 0);
    }
}

#[test]
fn ihtc_size_guarantee_random() {
    let mut rng = Xoshiro256::seed_from_u64(0xCAFE);
    for case in 0..12 {
        let spec = random_mixture(&mut rng);
        let n = 300 + rng.next_below(1200) as usize;
        let t = 2 + rng.next_below(2) as usize;
        let m = 1 + rng.next_below(3) as usize;
        let k = 2 + rng.next_below(3) as usize;
        let ds = spec.sample(n, 7000 + case);
        let r = Ihtc::new(t, m, FinalClusterer::KMeans { k, restarts: 2 })
            .run(&ds.points)
            .unwrap();
        let guarantee = t.pow(m as u32);
        // Guarantee applies when the reduction actually ran m iterations.
        if r.itis.iterations() == m {
            assert!(
                metrics::min_cluster_size(&r.assignments) >= guarantee,
                "case {case}: t={t} m={m}"
            );
        }
    }
}

#[test]
fn knn_backends_agree_on_random_dims() {
    let mut rng = Xoshiro256::seed_from_u64(0xD1CE);
    for case in 0..10 {
        let spec = random_mixture(&mut rng);
        let n = 50 + rng.next_below(300) as usize;
        let k = 1 + rng.next_below(6) as usize;
        let ds = spec.sample(n, 8000 + case);
        if k >= n {
            continue;
        }
        let a = knn_brute(&ds.points, k).unwrap();
        let b = knn_auto(&ds.points, k).unwrap();
        for i in 0..n {
            for (x, y) in a.distances(i).iter().zip(b.distances(i)) {
                assert!(
                    (x - y).abs() <= 1e-3 * (1.0 + x.abs()),
                    "case {case} row {i}"
                );
            }
        }
    }
}

#[test]
fn hac_cut_partition_properties() {
    let mut rng = Xoshiro256::seed_from_u64(0xFACE);
    for case in 0..10 {
        let spec = random_mixture(&mut rng);
        let n = 20 + rng.next_below(150) as usize;
        let ds = spec.sample(n, 9000 + case);
        let linkage = match rng.next_below(4) {
            0 => Linkage::Ward,
            1 => Linkage::Average,
            2 => Linkage::Complete,
            _ => Linkage::Single,
        };
        let k = 1 + rng.next_below((n as u64).min(6)) as usize;
        let labels =
            hac_cut(&ds.points, k, &HacConfig { linkage, ..Default::default() }).unwrap();
        assert_eq!(labels.len(), n);
        assert_eq!(metrics::num_clusters(&labels), k, "case {case} {linkage:?}");
    }
}

#[test]
fn metrics_consistency_random() {
    let mut rng = Xoshiro256::seed_from_u64(0xAB1E);
    for _ in 0..15 {
        let n = 20 + rng.next_below(200) as usize;
        let ka = 1 + rng.next_below(5) as u32;
        let kb = 1 + rng.next_below(5) as u32;
        let a: Vec<u32> = (0..n).map(|_| rng.next_below(ka as u64) as u32).collect();
        let b: Vec<u32> = (0..n).map(|_| rng.next_below(kb as u64) as u32).collect();
        // ARI/NMI symmetric; self-comparison = 1 (when not all-identical-degenerate).
        let ab = metrics::adjusted_rand_index(&a, &b).unwrap();
        let ba = metrics::adjusted_rand_index(&b, &a).unwrap();
        assert!((ab - ba).abs() < 1e-9);
        let nab = metrics::normalized_mutual_info(&a, &b).unwrap();
        let nba = metrics::normalized_mutual_info(&b, &a).unwrap();
        assert!((nab - nba).abs() < 1e-9);
        assert!((0.0..=1.0).contains(&nab));
        assert!((metrics::adjusted_rand_index(&a, &a).unwrap() - 1.0).abs() < 1e-9);
        // Accuracy ≥ fraction of the largest truth class (majority rule).
        let acc = metrics::prediction_accuracy(&a, &b).unwrap();
        let sizes = metrics::cluster_sizes(&a);
        let majority = *sizes.iter().max().unwrap() as f64 / n as f64;
        if kb == 1 {
            assert!(acc >= majority - 1e-9, "{acc} < {majority}");
        }
        assert!(acc <= 1.0 && acc >= 0.0);
    }
}

#[test]
fn paper_mixture_spec_matches_section4() {
    // Pin the simulation model to the paper's exact parameters.
    let spec = paper_mixture_spec();
    assert_eq!(spec.components.len(), 3);
    let w: Vec<f64> = spec.components.iter().map(|c| c.weight).collect();
    assert_eq!(w, vec![0.5, 0.3, 0.2]);
    assert_eq!(spec.components[0].mean, vec![1.0, 2.0]);
    assert_eq!(spec.components[1].mean, vec![7.0, 8.0]);
    assert_eq!(spec.components[2].mean, vec![3.0, 5.0]);
    // Variances: diag(1,.5), diag(2,1), diag(3,4) → stds are sqrt.
    let close = |a: f64, b: f64| (a - b).abs() < 1e-12;
    assert!(close(spec.components[0].std[0], 1.0));
    assert!(close(spec.components[0].std[1], 0.5f64.sqrt()));
    assert!(close(spec.components[1].std[0], 2.0f64.sqrt()));
    assert!(close(spec.components[2].std[1], 2.0));
}

#[test]
fn tc_refinements_preserve_invariants_random() {
    let mut rng = Xoshiro256::seed_from_u64(0x5EED5);
    for case in 0..10 {
        let spec = random_mixture(&mut rng);
        let n = 100 + rng.next_below(400) as usize;
        let t = 2 + rng.next_below(4) as usize;
        let ds = spec.sample(n, 10_000 + case);
        let knn = knn_auto(&ds.points, t - 1).unwrap();
        let g = NeighborGraph::from_knn(&knn);
        let mut r = threshold_cluster_graph(&g, &ds.points, &TcConfig::new(t));
        ihtc::tc::refine::reassign_boundary(&mut r, &g, &ds.points, t);
        ihtc::tc::refine::split_large_clusters(&mut r, &ds.points, t);
        let sizes = metrics::cluster_sizes(&r.assignments);
        assert!(sizes.iter().all(|&s| s >= t), "case {case}: {sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), n);
    }
}

#[test]
fn json_parser_roundtrip_fuzz() {
    use ihtc::config::json::Json;
    // Deterministic "fuzz": generate random JSON values, serialize by
    // hand, reparse, compare structure.
    let mut rng = Xoshiro256::seed_from_u64(0x15E1);
    fn gen(rng: &mut Xoshiro256, depth: usize) -> (String, usize) {
        match if depth > 2 { rng.next_below(4) } else { rng.next_below(6) } {
            0 => ("null".into(), 1),
            1 => ("true".into(), 1),
            2 => (format!("{}", (rng.next_below(2000) as i64) - 1000), 1),
            3 => (format!("\"s{}\"", rng.next_below(1000)), 1),
            4 => {
                let n = rng.next_below(4) as usize;
                let mut items = Vec::new();
                let mut count = 1;
                for _ in 0..n {
                    let (s, c) = gen(rng, depth + 1);
                    items.push(s);
                    count += c;
                }
                (format!("[{}]", items.join(",")), count)
            }
            _ => {
                let n = rng.next_below(4) as usize;
                let mut items = Vec::new();
                let mut count = 1;
                for i in 0..n {
                    let (s, c) = gen(rng, depth + 1);
                    items.push(format!("\"k{i}\":{s}"));
                    count += c;
                }
                (format!("{{{}}}", items.join(",")), count)
            }
        }
    }
    for _ in 0..200 {
        let (doc, _) = gen(&mut rng, 0);
        let parsed = Json::parse(&doc).unwrap_or_else(|e| panic!("doc {doc}: {e}"));
        // Reparse of a canonical re-render must be identical.
        let rendered = render(&parsed);
        assert_eq!(Json::parse(&rendered).unwrap(), parsed, "doc {doc}");
    }
    fn render(v: &Json) -> String {
        match v {
            Json::Null => "null".into(),
            Json::Bool(b) => b.to_string(),
            Json::Number(n) => format!("{n}"),
            Json::String(s) => format!("\"{s}\""),
            Json::Array(a) => {
                format!("[{}]", a.iter().map(render).collect::<Vec<_>>().join(","))
            }
            Json::Object(o) => format!(
                "{{{}}}",
                o.iter()
                    .map(|(k, v)| format!("\"{k}\":{}", render(v)))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        }
    }
}
