//! Shared-executor determinism suite: the one work-stealing thread team
//! that now backs every parallel layer must never change output bytes —
//! not under worker-count changes, not under the in-flight reduce-batch
//! cap, not under priority classes, not under kd-forest sharding, not
//! under steal-policy/fairness knobs, and not when one reduce batch is
//! adversarially skewed so the stealing actually rebalances the budget
//! mid-stream.

use ihtc::config::{DataSource, PipelineConfig};
use ihtc::coordinator::driver::{ingest_streaming, StreamedReduction};
use ihtc::coordinator::parallel_knn;
use ihtc::exec::{Executor, ExecutorConfig, Priority, StealPolicy};
use ihtc::itis::PrototypeKind;
use ihtc::knn::knn_brute;
use std::io::Write;
use std::sync::Arc;

/// Write a deliberately *skewed* CSV: the first source shard
/// (rows `0..shard`) is a dense near-duplicate clump — its level-0 TC
/// and k-NN are far more expensive than its siblings' — while the rest
/// of the stream is an easy well-separated grid. Under the retired
/// static split (`workers / reduce_stages` threads per stage), the
/// stage unlucky enough to draw the clump ran it on a sliver of the
/// budget while its siblings idled; with the shared executor the whole
/// team converges on it. Either way the bytes must be identical — this
/// source exists so the property is exercised where stealing matters.
fn write_skewed_csv(n: usize, shard: usize) -> String {
    let dir = std::env::temp_dir().join("ihtc_exec_determinism");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("skewed_{n}_{shard}.csv"));
    let mut w = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
    writeln!(w, "x,y").unwrap();
    for i in 0..n {
        if i < shard {
            // Hard block: thousands of points inside a 0.01-wide clump
            // (near-ties everywhere — worst case for kd-tree descent).
            let j = i as f64;
            writeln!(w, "{:.6},{:.6}", 1e-4 * (j % 97.0), 1e-4 * (j % 89.0)).unwrap();
        } else {
            // Easy tail: well-separated lattice.
            let j = (i - shard) as f64;
            writeln!(w, "{:.6},{:.6}", 10.0 + (j % 50.0) * 3.0, (j / 50.0).floor() * 3.0)
                .unwrap();
        }
    }
    w.flush().unwrap();
    path.to_string_lossy().into_owned()
}

fn skewed_config(path: &str, workers: usize, stages: usize, knn_shards: usize) -> PipelineConfig {
    PipelineConfig {
        source: DataSource::Csv { path: path.into(), label_column: None },
        streaming: true,
        prototype: PrototypeKind::WeightedCentroid,
        threshold: 3,
        workers,
        reduce_stages: stages,
        knn_shards,
        shard_size: 500,
        ..Default::default()
    }
}

fn assert_reductions_identical(got: &StreamedReduction, base: &StreamedReduction, what: &str) {
    assert_eq!(got.n, base.n, "{what}: n");
    let gb: Vec<u32> = got.prototypes.data().iter().map(|v| v.to_bits()).collect();
    let bb: Vec<u32> = base.prototypes.data().iter().map(|v| v.to_bits()).collect();
    assert_eq!(gb, bb, "{what}: prototype bytes");
    assert_eq!(got.weights, base.weights, "{what}: weights");
    assert_eq!(
        got.level0.read_assignments().unwrap(),
        base.level0.read_assignments().unwrap(),
        "{what}: assignments"
    );
    assert_eq!(got.labels, base.labels, "{what}: labels");
    assert_eq!(got.moments.count, base.moments.count, "{what}: moment count");
    assert_eq!(got.moments.sum, base.moments.sum, "{what}: moment sums");
    assert_eq!(got.moments.cross, base.moments.cross, "{what}: moment cross");
}

#[test]
fn skewed_stage_byte_identical_across_workers_stages_knn_shards() {
    // The acceptance grid: one batch's shards are deliberately harder,
    // and every workers × reduce_stages × knn_shards combination must
    // produce a byte-identical StreamedReduction while sharing one
    // executor. `reduce_stages` is now an in-flight batch cap, not a
    // thread budget, so stages > workers is a legal (and exercised)
    // grid point — more batches queued than workers to claim them.
    let path = write_skewed_csv(4000, 500);
    let base = ingest_streaming(&skewed_config(&path, 1, 1, 1)).unwrap();
    assert_eq!(base.n, 4000);
    for workers in [1usize, 2, 4] {
        for stages in [1usize, 2, 4] {
            for knn_shards in [1usize, 2] {
                let cfg = skewed_config(&path, workers, stages, knn_shards);
                cfg.validate().unwrap();
                let got = ingest_streaming(&cfg).unwrap();
                assert_reductions_identical(
                    &got,
                    &base,
                    &format!("workers={workers} stages={stages} knn_shards={knn_shards}"),
                );
            }
        }
    }
}

#[test]
fn priority_classes_never_change_bytes() {
    // The priority class steers *which* queue the reduce batches wait
    // in, never what they compute: for every class the full
    // reduce_stages × workers grid must reproduce the serial oracle
    // byte-for-byte. (Non-Normal classes only validate with streaming
    // on — skewed_config sets it.)
    let path = write_skewed_csv(3000, 500);
    let base = ingest_streaming(&skewed_config(&path, 1, 1, 1)).unwrap();
    assert_eq!(base.n, 3000);
    for priority in [Priority::High, Priority::Normal, Priority::Bulk] {
        for workers in [1usize, 2, 4] {
            for stages in [1usize, 2, 4] {
                let mut cfg = skewed_config(&path, workers, stages, 1);
                cfg.reduce_priority = priority;
                cfg.validate().unwrap();
                let got = ingest_streaming(&cfg).unwrap();
                assert_reductions_identical(
                    &got,
                    &base,
                    &format!("priority={priority:?} workers={workers} stages={stages}"),
                );
            }
        }
    }
}

#[test]
fn steal_policy_and_fairness_never_change_bytes() {
    // Scheduling knobs are scheduling-only: all four combinations give
    // the byte-identical reduction on the skewed stream.
    let path = write_skewed_csv(3000, 500);
    let base = ingest_streaming(&skewed_config(&path, 1, 1, 1)).unwrap();
    for steal in [StealPolicy::Fifo, StealPolicy::Lifo] {
        for fair in [false, true] {
            let mut cfg = skewed_config(&path, 4, 4, 1);
            cfg.steal = steal;
            cfg.fair_stages = fair;
            let got = ingest_streaming(&cfg).unwrap();
            assert_reductions_identical(&got, &base, &format!("steal={steal:?} fair={fair}"));
        }
    }
}

#[test]
fn steal_heavy_concurrent_submitters_keep_knn_byte_parity() {
    // Cross-layer steal-heavy smoke: several threads submit pooled k-NN
    // batches into ONE executor concurrently (the reduce-stage usage
    // shape), racing a deliberately expensive competing batch. Every
    // submitter's output must stay byte-identical to the serial oracle.
    let ds = ihtc::data::synth::gaussian_mixture_paper(3000, 0xEC5EED);
    let oracle = knn_brute(&ds.points, 4).unwrap();
    let exec = Arc::new(Executor::with_config(ExecutorConfig {
        workers: 4,
        steal: StealPolicy::Fifo,
        fair_stages: true,
    }));
    // Competing load: keep the team busy while the k-NN batches run.
    let load = {
        let exec = Arc::clone(&exec);
        std::thread::spawn(move || {
            exec.run_tasks((0..32usize).collect(), |t| {
                let mut acc = 0u64;
                for i in 0..500_000u64 {
                    acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i ^ t as u64);
                }
                Ok(acc)
            })
            .unwrap()
        })
    };
    let mut joins = Vec::new();
    for s in 0..3 {
        let exec = Arc::clone(&exec);
        let points = ds.points.clone();
        let want_idx = oracle.indices.clone();
        let want_bits: Vec<u32> = oracle.dists.iter().map(|v| v.to_bits()).collect();
        joins.push(std::thread::spawn(move || {
            for round in 0..3 {
                let got = parallel_knn(&points, 4, &exec).unwrap();
                assert_eq!(got.indices, want_idx, "submitter {s} round {round}");
                let bits: Vec<u32> = got.dists.iter().map(|v| v.to_bits()).collect();
                assert_eq!(bits, want_bits, "submitter {s} round {round}");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(load.join().unwrap().len(), 32);
}
