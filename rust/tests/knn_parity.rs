//! Parallel/serial parity property suite: the pooled k-NN paths
//! (`parallel_knn`, pooled `knn_auto`) must produce **byte-identical**
//! `KnnLists` to the `knn_brute` oracle on synthetic mixtures, across
//! worker counts 1/2/4. This pins down the deterministic
//! `(distance, index)` candidate order every backend shares — without
//! it, distance ties would resolve differently per backend and per
//! worker count.

use ihtc::coordinator::parallel_knn;
use ihtc::exec::Executor;
use ihtc::data::synth::gaussian_mixture_paper;
use ihtc::knn::{knn_auto_with, knn_brute, KnnLists};

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_identical(got: &KnnLists, oracle: &KnnLists, what: &str) {
    assert_eq!(got.k, oracle.k, "{what}: k");
    assert_eq!(got.indices, oracle.indices, "{what}: neighbor indices");
    assert_eq!(bits(&got.dists), bits(&oracle.dists), "{what}: distance bits");
}

#[test]
fn pooled_knn_byte_identical_to_brute() {
    // n spans the serial/parallel routing thresholds (256, 2048) and the
    // parallel kd-build threshold region; k spans t*−1 for small and
    // large thresholds.
    for &(n, k) in &[(300usize, 1usize), (1000, 3), (2600, 2), (2600, 7)] {
        let ds = gaussian_mixture_paper(n, 0xBEE5 + (n + k) as u64);
        let oracle = knn_brute(&ds.points, k).unwrap();
        for workers in [1usize, 2, 4] {
            let pool = Executor::new(workers);
            let par = parallel_knn(&ds.points, k, &pool).unwrap();
            assert_identical(&par, &oracle, &format!("parallel_knn n={n} k={k} w={workers}"));
            let auto = knn_auto_with(&ds.points, k, &pool).unwrap();
            assert_identical(&auto, &oracle, &format!("knn_auto n={n} k={k} w={workers}"));
        }
    }
}

#[test]
fn pooled_knn_byte_identical_past_parallel_build_threshold() {
    // Exercise the parallel kd-tree *build* (engages at n ≥ 8192) and
    // pool-sharded queries together against the oracle.
    let n = 9000;
    let ds = gaussian_mixture_paper(n, 0xFA57);
    let oracle = knn_brute(&ds.points, 3).unwrap();
    for workers in [1usize, 2, 4] {
        let pool = Executor::new(workers);
        let par = parallel_knn(&ds.points, 3, &pool).unwrap();
        assert_identical(&par, &oracle, &format!("parallel_knn n={n} w={workers}"));
        let auto = knn_auto_with(&ds.points, 3, &pool).unwrap();
        assert_identical(&auto, &oracle, &format!("knn_auto n={n} w={workers}"));
    }
}

#[test]
fn pooled_knn_handles_duplicate_ties_identically() {
    // Heavy exact-tie workload: 60% duplicated points. Ties are where
    // nondeterminism would hide; the shared candidate order must keep
    // every backend identical to the oracle.
    let n = 1500;
    let mut data = Vec::with_capacity(n * 2);
    for i in 0..n {
        if i % 5 < 3 {
            data.push(1.25f32);
            data.push(-0.5f32);
        } else {
            data.push((i % 97) as f32 * 0.1);
            data.push((i % 89) as f32 * 0.2);
        }
    }
    let m = ihtc::linalg::Matrix::from_vec(data, n, 2).unwrap();
    let oracle = knn_brute(&m, 4).unwrap();
    for workers in [1usize, 2, 4] {
        let pool = Executor::new(workers);
        let par = parallel_knn(&m, 4, &pool).unwrap();
        assert_identical(&par, &oracle, &format!("duplicates parallel_knn w={workers}"));
        let auto = knn_auto_with(&m, 4, &pool).unwrap();
        assert_identical(&auto, &oracle, &format!("duplicates knn_auto w={workers}"));
    }
}
