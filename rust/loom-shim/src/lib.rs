//! std-backed shim for the subset of the [loom](https://docs.rs/loom)
//! API that `ihtc`'s `sync` facade and loom scenarios use.
//!
//! See Cargo.toml for why this exists (offline dependency resolution).
//! The re-exports are deliberately *just* re-exports: when CI swaps the
//! real loom in, any API drift fails the build loudly instead of
//! silently testing against different semantics.

/// Run a model scenario. The real loom explores every interleaving the
/// preemption bound allows; the shim runs the body once on real
/// threads — a smoke execution that keeps the scenarios runnable (and
/// compiling) offline.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    f();
}

/// `loom::sync` — std re-exports.
pub mod sync {
    pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

    /// `loom::sync::atomic` — std re-exports.
    pub mod atomic {
        pub use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    }
}

/// `loom::thread` — std re-exports.
pub mod thread {
    pub use std::thread::JoinHandle;

    /// Plain spawn (the real loom registers the thread with the model).
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::spawn(f)
    }
}
