//! End-to-end system driver (the EXPERIMENTS.md §E2E run).
//!
//! Exercises every layer on a real workload: the streaming coordinator
//! ingests the §4 Gaussian mixture at n = 10⁵ shard-by-shard with bounded
//! queues, k-NN graph construction is sharded across the work-stealing
//! pool (and through the PJRT AOT artifacts when available), ITIS reduces,
//! k-means clusters the prototypes, labels are backed out, and the
//! paper's headline metric is reported: **m = 1 should roughly halve
//! end-to-end runtime and peak memory at unchanged accuracy**.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use ihtc::config::{Backend, DataSource, PipelineConfig};
use ihtc::coordinator::driver;
use ihtc::report::Table;

#[global_allocator]
static ALLOC: ihtc::memtrack::CountingAllocator = ihtc::memtrack::CountingAllocator;

fn main() -> ihtc::Result<()> {
    let n = 100_000;
    let have_artifacts = ihtc::runtime::Engine::default_dir().join("manifest.json").exists();
    println!("end-to-end pipeline on the §4 GMM, n={n}; PJRT artifacts: {have_artifacts}\n");

    let mut table = Table::new(
        "E2E: IHTC + k-means through the streaming coordinator",
        &["backend", "m", "seconds", "peak_MB", "accuracy", "BSS/TSS", "prototypes", "blocked_ms"],
    );

    // The PJRT rows use a smaller n: the AOT brute-force tiling is an
    // architecture/correctness path on this CPU-interpret substrate
    // (O(n²) blocks vs the native kd-tree's O(n log n); see EXPERIMENTS.md
    // §Perf for the per-block numbers and the TPU projection).
    let backends: Vec<(&str, Backend, usize)> = if have_artifacts {
        vec![("native", Backend::Native, n), ("pjrt", Backend::Pjrt, 20_000)]
    } else {
        vec![("native", Backend::Native, n)]
    };

    let mut native_times: Vec<(usize, f64)> = Vec::new();
    for (bname, backend, bn) in &backends {
        for m in [0usize, 1, 2, 3] {
            let cfg = PipelineConfig {
                name: format!("e2e-{bname}-m{m}"),
                source: DataSource::PaperMixture { n: *bn },
                iterations: m,
                backend: *backend,
                workers: 0, // auto
                shard_size: 8_192,
                ..Default::default()
            };
            let t0 = std::time::Instant::now();
            ihtc::memtrack::reset_peak();
            let base = ihtc::memtrack::live_bytes();
            let (_, report) = driver::run(&cfg)?;
            let peak = ihtc::memtrack::peak_bytes().saturating_sub(base);
            let secs = t0.elapsed().as_secs_f64();
            let blocked_ms: u128 =
                report.stages.iter().map(|s| s.blocked.as_millis()).sum();
            table.push_row(vec![
                bname.to_string(),
                m.to_string(),
                format!("{secs:.3}"),
                ihtc::memtrack::fmt_mb(peak),
                report.accuracy.map(|a| format!("{a:.4}")).unwrap_or_default(),
                format!("{:.4}", report.bss_tss),
                report.prototypes.to_string(),
                blocked_ms.to_string(),
            ]);
            if *backend == Backend::Native {
                native_times.push((m, secs));
            }
        }
    }
    println!("{}", table.render());

    // Headline check: clustering phase shrinks with m (end-to-end time
    // includes the fixed ingest/knn cost, so compare m=1 vs m=0 loosely).
    if let (Some(&(_, t0)), Some(&(_, t1))) = (
        native_times.iter().find(|(m, _)| *m == 0),
        native_times.iter().find(|(m, _)| *m == 1),
    ) {
        println!(
            "headline: m=1 end-to-end is {:.2}× the m=0 time (clustering-phase \
             reduction is steeper; see EXPERIMENTS.md)",
            t1 / t0
        );
    }
    Ok(())
}
