//! Domain scenario: the paper's Covertype experiment (§5, Tables 4/6)
//! through the streaming coordinator, with HAC as the final clusterer.
//!
//! Covertype is the paper's largest UCI dataset (581 012 × 6, 7 classes);
//! `hclust` cannot touch it directly. The pipeline: synthetic analogue →
//! standardize (streaming moments) → PCA → sharded k-NN / ITIS → HAC on
//! the prototypes → back-out. Per-stage metrics show where the time and
//! the backpressure go.
//!
//! ```bash
//! cargo run --release --example streaming_covertype
//! ```

use ihtc::cluster::hac::Linkage;
use ihtc::config::{DataSource, PipelineConfig};
use ihtc::coordinator::driver;
use ihtc::hybrid::FinalClusterer;

#[global_allocator]
static ALLOC: ihtc::memtrack::CountingAllocator = ihtc::memtrack::CountingAllocator;

fn main() -> ihtc::Result<()> {
    // scale_div 8 → ~72k points: big enough that direct HAC (O(n²) memory
    // ≈ 10 GB) is genuinely out of reach, small enough for a demo run.
    let mut cfg = PipelineConfig {
        name: "covertype-hac".into(),
        source: DataSource::Analogue { name: "covertype".into(), scale_div: 8 },
        standardize: true,
        pca_variance: Some(0.99),
        threshold: 2,
        clusterer: FinalClusterer::Hac { k: 7, linkage: Linkage::Ward },
        workers: 0,
        shard_size: 4_096,
        queue_capacity: 4,
        ..Default::default()
    };

    println!("Covertype-analogue through the streaming coordinator, HAC hybrid\n");
    for m in [3usize, 4, 5] {
        cfg.iterations = m;
        cfg.name = format!("covertype-hac-m{m}");
        match driver::run(&cfg) {
            Ok((_, report)) => {
                println!("{}", report.render());
            }
            Err(e) => {
                // Small m leaves too many prototypes for HAC's n² memory —
                // exactly the infeasibility the paper's Table 6 shows.
                println!("m={m}: infeasible ({e})\n");
            }
        }
    }
    println!(
        "Direct HAC on the full set would need ~{:.0} GB for the distance matrix;\n\
         ITIS reduced it to a few thousand prototypes first (paper §4.2).",
        (72_626f64 * 72_626.0 / 2.0 * 4.0) / 1e9
    );

    // Out-of-core mode: the same analogue streamed shard-by-shard with
    // level-0 TC fused into ingest (`streaming: true`). The full matrix
    // is never materialized — compare the ingest phase's peak bytes
    // against the materialized runs above.
    println!("\nSame workload, fused streaming ingest (out-of-core):\n");
    cfg.streaming = true;
    cfg.prototype = ihtc::itis::PrototypeKind::WeightedCentroid;
    cfg.iterations = 4;
    cfg.name = "covertype-hac-stream-m4".into();
    match driver::run(&cfg) {
        Ok((_, report)) => println!("{}", report.render()),
        Err(e) => println!("streaming m=4: infeasible ({e})\n"),
    }
    Ok(())
}
