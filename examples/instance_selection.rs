//! ITIS as a standalone instance-selection method (§3.1).
//!
//! A researcher wants the data reduced by a factor α so a downstream
//! procedure becomes affordable. This example reduces a 200k-point
//! mixture by α = 50 two ways and compares them, reproducing the
//! Appendix A trade-off:
//!
//! * iterate at t* = 2 until the factor is reached (the paper's
//!   recommendation), vs.
//! * a single iteration at t* = α (approximate-optimality preserved but
//!   slower, since TC's cost grows with t*).
//!
//! ```bash
//! cargo run --release --example instance_selection
//! ```

use ihtc::data::synth::gaussian_mixture_paper;
use ihtc::itis::{itis, ItisConfig, PrototypeKind, StopRule};
use ihtc::metrics;

#[global_allocator]
static ALLOC: ihtc::memtrack::CountingAllocator = ihtc::memtrack::CountingAllocator;

fn quantization_error(points: &ihtc::linalg::Matrix, r: &ihtc::itis::ItisResult) -> f64 {
    // Mean squared distance from each unit to its prototype: how faithful
    // the reduced set is to the original data.
    let map = r.unit_to_prototype();
    let mut total = 0.0f64;
    for i in 0..points.rows() {
        total += ihtc::linalg::sq_dist(points.row(i), r.prototypes.row(map[i] as usize)) as f64;
    }
    total / points.rows() as f64
}

fn main() -> ihtc::Result<()> {
    let n = 200_000;
    let alpha = 50.0;
    let ds = gaussian_mixture_paper(n, 11);
    println!("instance selection on n={n}, target reduction α={alpha}\n");

    // Strategy A: iterate at small threshold.
    let t0 = std::time::Instant::now();
    let (iterated, peak_a) = ihtc::memtrack::measure(|| {
        itis(&ds.points, &ItisConfig::reduction(2, alpha))
    });
    let iterated = iterated?;
    let secs_a = t0.elapsed().as_secs_f64();

    // Strategy B: one iteration at t* = α.
    let t0 = std::time::Instant::now();
    let (single, peak_b) = ihtc::memtrack::measure(|| {
        itis(
            &ds.points,
            &ItisConfig {
                threshold: alpha as usize,
                stop: StopRule::Iterations(1),
                prototype: PrototypeKind::Centroid,
                seed_order: ihtc::tc::SeedOrder::Natural,
                min_prototypes: 1,
            },
        )
    });
    let single = single?;
    let secs_b = t0.elapsed().as_secs_f64();

    let single_name = format!("single t*={}", alpha as usize);
    for (name, r, secs, peak) in [
        ("iterated t*=2", &iterated, secs_a, peak_a),
        (single_name.as_str(), &single, secs_b, peak_b),
    ] {
        println!(
            "{name:<16} m={} prototypes={:>5} reduction=×{:>6.1} time={secs:>7.3}s \
             peak={}MB qerr={:.4}",
            r.iterations(),
            r.prototypes.rows(),
            r.reduction_factor(),
            ihtc::memtrack::fmt_mb(peak),
            quantization_error(&ds.points, r),
        );
    }

    // Fidelity check: cluster-label purity of the prototypes (each
    // prototype inherits the majority class of its units).
    let truth = ds.labels.as_ref().unwrap();
    for (name, r) in [("iterated", &iterated), ("single-shot", &single)] {
        let map = r.unit_to_prototype();
        let np = r.prototypes.rows();
        let mut votes = vec![[0u32; 3]; np];
        for (i, &p) in map.iter().enumerate() {
            votes[p as usize][truth[i] as usize] += 1;
        }
        let proto_labels: Vec<u32> = votes
            .iter()
            .map(|v| (0..3).max_by_key(|&c| v[c]).unwrap() as u32)
            .collect();
        let backed = r.back_out(&proto_labels)?;
        let purity = metrics::prediction_accuracy(truth, &backed)?;
        println!("{name:<12} prototype purity (majority back-out accuracy): {purity:.4}");
    }
    println!("\nBoth reach α; iterating at t*=2 is the faster route (Appendix A).");
    Ok(())
}
