//! Quickstart: the paper's Figure 1/2 walkthrough, runnable.
//!
//! Draws a small sample from the §4 Gaussian mixture, shows what one TC
//! pass at t* = 2 does (many tiny clusters), iterates it into ITIS
//! prototypes, hybridizes with k-means, and backs the labels out — then
//! prints the same summary quantities the paper's illustrations annotate.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ihtc::data::synth::gaussian_mixture_paper;
use ihtc::hybrid::{FinalClusterer, Ihtc};
use ihtc::itis::{itis, ItisConfig};
use ihtc::metrics;
use ihtc::tc::{threshold_cluster, TcConfig};

fn main() -> ihtc::Result<()> {
    let n = 3_000;
    let ds = gaussian_mixture_paper(n, 7);
    let truth = ds.labels.as_ref().unwrap();
    println!("sampled n={n} points from the paper's 3-component bivariate GMM\n");

    // --- Step 1: one TC pass (Figure 1, panels a-c). ---
    let tc = threshold_cluster(&ds.points, &TcConfig::new(2))?;
    let sizes = metrics::cluster_sizes(&tc.assignments);
    println!(
        "TC (t*=2): {} clusters, sizes min={} median={} max={}",
        tc.num_clusters,
        sizes.iter().min().unwrap(),
        {
            let mut s = sizes.clone();
            s.sort_unstable();
            s[s.len() / 2]
        },
        sizes.iter().max().unwrap()
    );
    let bottleneck = metrics::bottleneck(&ds.points, &tc.assignments, usize::MAX)?;
    println!("  max within-cluster distance (bottleneck objective): {bottleneck:.3}\n");

    // --- Step 2: iterate into ITIS prototypes (Figure 1, panels d-e). ---
    for m in 1..=4 {
        let r = itis(&ds.points, &ItisConfig::iterations(2, m))?;
        println!(
            "ITIS m={m}: {:>5} prototypes (reduction ×{:.1})",
            r.prototypes.rows(),
            r.reduction_factor()
        );
    }
    println!();

    // --- Step 3: IHTC = ITIS + k-means + back-out (Figure 2). ---
    for m in [0usize, 2] {
        let r = Ihtc::new(2, m, FinalClusterer::KMeans { k: 3, restarts: 6 }).run(&ds.points)?;
        let acc = metrics::prediction_accuracy(truth, &r.assignments)?;
        let ratio = metrics::bss_tss(&ds.points, &r.assignments)?;
        println!(
            "IHTC m={m}: k-means on {:>4} points → accuracy {:.4}, BSS/TSS {:.4}, \
             min cluster {:>4} (guarantee ≥ {})",
            r.num_prototypes(),
            acc,
            ratio,
            metrics::min_cluster_size(&r.assignments),
            2usize.pow(m as u32),
        );
    }
    println!("\nm=2 clusters 4× fewer points with matching accuracy — the paper's headline.");
    Ok(())
}
