"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``make artifacts``). Python runs ONCE here, at build time; the Rust
binary loads the emitted ``*.hlo.txt`` through the PJRT C API and never
calls back into Python.

Interchange format is HLO **text**, not serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is recorded in ``manifest.json`` with its input/output
signature so the Rust side can validate shapes at load time.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Tile geometry shared with rust/src/runtime/. Changing these requires
# re-running `make artifacts`; the manifest carries the actual values.
KNN_Q = 256    # query rows per knn_chunk call
KNN_R = 1024   # reference rows per knn_chunk call
# Neighbor-slot variants: each top-k round costs a full pass over the
# distance block (see model.knn_chunk), so the common t* = 2 case (k = 1)
# should not pay for 16 rounds. The runtime picks the smallest variant
# with enough slots. KNN_KS[-1] bounds the serviceable t* at 17.
KNN_KS = (2, 16)
KNN_K = KNN_KS[-1]
KM_N = 1024    # point rows per kmeans_assign call
KM_K = 16      # center slots (k ≤ 16 after padding)
DIM = 8        # feature dim (datasets are padded up to this)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals):
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in avals]


def lower_knn_chunk(k: int = KNN_K):
    f32 = jnp.float32
    i32 = jnp.int32
    fn = functools.partial(model.knn_chunk, k=k)
    args = (
        jax.ShapeDtypeStruct((KNN_Q, DIM), f32),
        jax.ShapeDtypeStruct((KNN_R, DIM), f32),
        jax.ShapeDtypeStruct((KNN_Q,), i32),
        jax.ShapeDtypeStruct((KNN_R,), i32),
    )
    lowered = jax.jit(fn).lower(*args)
    name = f"knn_chunk_q{KNN_Q}_r{KNN_R}_d{DIM}_k{k}"
    return name, lowered, args

def lower_kmeans_assign():
    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((KM_N, DIM), f32),
        jax.ShapeDtypeStruct((KM_K, DIM), f32),
        jax.ShapeDtypeStruct((KM_K,), f32),
        jax.ShapeDtypeStruct((KM_N,), f32),
    )
    lowered = jax.jit(model.kmeans_assign).lower(*args)
    name = f"kmeans_assign_n{KM_N}_k{KM_K}_d{DIM}"
    return name, lowered, args


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "tile": {
            "knn_q": KNN_Q,
            "knn_r": KNN_R,
            "knn_k": KNN_K,
            "km_n": KM_N,
            "km_k": KM_K,
            "dim": DIM,
        },
        "artifacts": [],
    }
    jobs = [lower_knn_chunk(k) for k in KNN_KS]
    jobs.append(lower_kmeans_assign())
    for name, lowered, in_args in jobs:
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.tree_util.tree_leaves(lowered.out_info)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": _sig(in_args),
                "outputs": [
                    {"shape": list(o.shape), "dtype": str(o.dtype)} for o in out_avals
                ],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
