"""L2: the JAX compute graphs the Rust coordinator executes via PJRT.

Two request-path functions, both built on the L1 Pallas pairwise kernel
(`kernels.pairwise`), both AOT-lowered by `aot.py` to fixed-shape HLO
text artifacts:

* ``knn_chunk`` — one tile of exact k-NN: a block of queries against a
  block of references, masked for padding and self-matches, reduced with
  ``lax.top_k``. The Rust side merges per-block top-k lists across
  reference blocks (`rust/src/runtime/`).
* ``kmeans_assign`` — one blocked Lloyd assignment step: nearest (live)
  center per point plus the per-cluster weighted sums/counts and the
  block's WCSS contribution, so the Rust driver can finish the update
  step with a pure reduction.

Masking conventions: padded reference rows carry ``r_ids == -1``; padded
query/point rows carry ``point_mask == 0``; padded centers carry
``center_mask == 0``. All shapes here are static — the AOT artifacts are
compiled once per tile geometry and the Rust runtime pads into them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.pairwise import pairwise_sq_dists

# Distance added to masked-out candidates; large enough to lose every
# argmin against real data, small enough to stay finite in f32 math.
MASK_BIG = 1e30


def knn_chunk(q, r, q_ids, r_ids, *, k: int):
    """Top-``k`` nearest references for each query row.

    Args:
      q: ``(Q, D)`` query block (padded rows allowed; give them ids -1).
      r: ``(R, D)`` reference block.
      q_ids: ``(Q,)`` int32 global ids of the query rows.
      r_ids: ``(R,)`` int32 global ids of the reference rows, -1 = padding.
      k: neighbors per query (static).

    Returns:
      ``(dists, ids)``: ``(Q, k)`` squared distances (``MASK_BIG`` slots
      mean "no candidate") and the matching ``(Q, k)`` int32 global ids
      (-1 where invalid).
    """
    if k > r.shape[0]:
        raise ValueError(f"k={k} exceeds reference block R={r.shape[0]}")
    d2 = pairwise_sq_dists(q, r)
    invalid = (r_ids[None, :] == q_ids[:, None]) | (r_ids[None, :] < 0)
    d2 = jnp.where(invalid, MASK_BIG, d2)
    # Top-k extraction, chosen for this runtime after three dead ends
    # (EXPERIMENTS.md §Perf): (1) lax.top_k lowers to the `topk(..,
    # largest=true)` HLO custom op, which xla_extension 0.5.1's text
    # parser rejects outright; (2) jnp.argsort lowers to the classic
    # `sort` op, which the 0.5.1 CPU backend executes with a per-element
    # comparator call (21.6 ms/block measured); (3) jnp.argmin lowers to
    # a *variadic* reduce whose custom comparator has the same problem
    # (24.4 ms/block). What IS fast on that backend are plain monoid
    # reduces (min/max/add) and elementwise ops — so each of the k rounds
    # computes the row minimum with reduce-min, recovers its column with
    # an equality mask + reduce-max over the column iota, and masks the
    # winner out. k is a small compile-time constant (≤ 16), so the
    # unrolled loop stays tiny. Measured: 1.0 ms/block, 24× faster.
    col = jnp.arange(r.shape[0], dtype=jnp.int32)[None, :]
    cur = d2
    sel_d = []
    sel_i = []
    for _ in range(k):
        dmin = jnp.min(cur, axis=1)                       # plain reduce-min
        hit = cur == dmin[:, None]                        # elementwise
        idx = jnp.max(jnp.where(hit, col, -1), axis=1)    # plain reduce-max
        sel_d.append(dmin)
        sel_i.append(jnp.take(r_ids, idx))
        cur = jnp.where(col == idx[:, None], MASK_BIG, cur)
    dists = jnp.stack(sel_d, axis=1)
    ids = jnp.stack(sel_i, axis=1)
    ids = jnp.where(dists >= MASK_BIG, -1, ids)
    return dists, ids


def kmeans_assign(x, centers, center_mask, point_mask):
    """One blocked k-means assignment step.

    Args:
      x: ``(N, D)`` point block.
      centers: ``(K, D)`` current centers (padded rows allowed).
      center_mask: ``(K,)`` 1.0 for live centers, 0.0 for padding.
      point_mask: ``(N,)`` 1.0 for live points, 0.0 for padding.

    Returns:
      ``assign``: ``(N,)`` int32 nearest live center per point;
      ``sums``: ``(K, D)`` masked per-cluster coordinate sums;
      ``counts``: ``(K,)`` masked per-cluster point counts;
      ``wcss``: scalar masked within-cluster sum of squares.
    """
    d2 = pairwise_sq_dists(x, centers)
    d2 = d2 + (1.0 - center_mask)[None, :] * MASK_BIG
    assign = jnp.argmin(d2, axis=1)
    mind = jnp.min(d2, axis=1)
    k = centers.shape[0]
    oh = (assign[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)
    oh = oh * point_mask[:, None]
    sums = oh.T @ x
    counts = jnp.sum(oh, axis=0)
    wcss = jnp.sum(mind * point_mask)
    return assign.astype(jnp.int32), sums, counts, wcss
