"""L1: tiled pairwise squared-Euclidean distance Pallas kernel.

This is the compute hot-spot of the whole system: IHTC's k-NN graph
construction and k-means assignment both reduce to dense blocks of
``‖q_i − r_j‖²``. The kernel computes one ``(TQ × TR)`` output tile per
grid step from a VMEM-resident query tile and a streamed reference tile,
with the cross term ``q · rᵀ`` as a single matmul (the MXU-friendly
formulation) and the norm corrections fused in-register — the distance
matrix never round-trips through HBM at tile granularity.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper is
CPU-R-code, so there is no GPU kernel to port; this is the canonical TPU
mapping of its inner loop. ``interpret=True`` is mandatory here — the CPU
PJRT plugin cannot execute Mosaic custom-calls, and interpret-mode lowers
to plain HLO ops that the Rust runtime executes natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shape: 128×256 f32 output tile = 128 KiB; with an 8-wide
# feature dim the three VMEM-resident blocks total ≈ 134 KiB, far under
# the ~16 MiB VMEM budget, leaving room for double buffering. See
# DESIGN.md §Perf for the block-shape sweep.
DEFAULT_TQ = 128
DEFAULT_TR = 256


def _pairwise_kernel(q_ref, r_ref, o_ref):
    """One output tile: o = max(‖q‖² + ‖r‖² − 2 q·rᵀ, 0)."""
    qt = q_ref[...]
    rt = r_ref[...]
    qn = jnp.sum(qt * qt, axis=1, keepdims=True)          # (TQ, 1)
    rn = jnp.sum(rt * rt, axis=1)[None, :]                # (1, TR)
    cross = jnp.dot(qt, rt.T, preferred_element_type=qt.dtype)  # MXU
    # Cancellation guard: the decomposition can dip slightly negative.
    o_ref[...] = jnp.maximum(qn + rn - 2.0 * cross, 0.0)


def _pick_tile(extent: int, preferred: int) -> int:
    """Largest divisor of ``extent`` that is ≤ ``preferred``."""
    t = min(preferred, extent)
    while extent % t != 0:
        t -= 1
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("tq", "tr"))
def pairwise_sq_dists(q, r, *, tq: int = DEFAULT_TQ, tr: int = DEFAULT_TR):
    """Squared Euclidean distances between rows of ``q`` and rows of ``r``.

    Args:
      q: ``(Q, D)`` query block.
      r: ``(R, D)`` reference block.
      tq, tr: preferred tile edge lengths (clipped to divisors).

    Returns:
      ``(Q, R)`` matrix of squared distances, elementwise ≥ 0.
    """
    (Q, D) = q.shape
    (R, D2) = r.shape
    if D != D2:
        raise ValueError(f"feature dims differ: {D} vs {D2}")
    tq = _pick_tile(Q, tq)
    tr = _pick_tile(R, tr)
    grid = (Q // tq, R // tr)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((tr, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tr), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, R), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, r)


def vmem_bytes(tq: int, tr: int, d: int, itemsize: int = 4) -> int:
    """Estimated VMEM footprint of one grid step (q, r, o tiles)."""
    return itemsize * (tq * d + tr * d + tq * tr)


def mxu_utilization_estimate(tq: int, tr: int, d: int) -> float:
    """Fraction of MXU lanes fed by the cross-term matmul.

    The 128×128 systolic array is fully fed when both output tile edges
    are ≥ 128 and the contraction dim keeps the pipeline busy; short
    contractions (d ≪ 128) cost a pipeline-fill overhead modeled as
    d/(d+2) per pass.
    """
    lane_fill = min(tq, 128) / 128.0 * min(tr, 128) / 128.0
    pipeline = d / (d + 2.0)
    return lane_fill * pipeline
