"""Pure-jnp oracles for the Pallas kernels.

Two independent formulations so the tests can triangulate:
``pairwise_ref`` uses the same norm decomposition as the kernel (bitwise
comparable up to reassociation) while ``pairwise_direct`` expands the
difference explicitly (numerically the ground truth).
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_ref(q, r):
    """‖q_i − r_j‖² via the ‖q‖² + ‖r‖² − 2 q·rᵀ decomposition."""
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    rn = jnp.sum(r * r, axis=1)[None, :]
    return jnp.maximum(qn + rn - 2.0 * (q @ r.T), 0.0)


def pairwise_direct(q, r):
    """‖q_i − r_j‖² via explicit differences (O(Q·R·D) memory)."""
    diff = q[:, None, :] - r[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def kmeans_assign_ref(x, centers, center_mask, point_mask, big: float = 1e30):
    """Reference k-means assignment step (see model.kmeans_assign)."""
    d2 = pairwise_direct(x, centers)
    d2 = d2 + (1.0 - center_mask)[None, :] * big
    assign = jnp.argmin(d2, axis=1)
    mind = jnp.min(d2, axis=1)
    k = centers.shape[0]
    oh = (assign[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)
    oh = oh * point_mask[:, None]
    sums = oh.T @ x
    counts = jnp.sum(oh, axis=0)
    wcss = jnp.sum(mind * point_mask)
    return assign.astype(jnp.int32), sums, counts, wcss
