"""L1 correctness: Pallas pairwise kernel vs the pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; fixed cases pin the numerics the
Rust native path mirrors (`rust/src/linalg/pairwise_sq_dists`).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.pairwise import (
    mxu_utilization_estimate,
    pairwise_sq_dists,
    vmem_bytes,
    _pick_tile,
)
from compile.kernels.ref import pairwise_direct, pairwise_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape, dtype=np.float32, scale=3.0):
    return (rng.standard_normal(shape) * scale).astype(dtype)


class TestFixedCases:
    def test_tiny_exact(self):
        q = jnp.array([[0.0, 0.0], [1.0, 1.0]], dtype=jnp.float32)
        r = jnp.array([[1.0, 0.0], [0.0, 3.0]], dtype=jnp.float32)
        out = pairwise_sq_dists(q, r)
        expect = jnp.array([[1.0, 9.0], [1.0, 5.0]])
        np.testing.assert_allclose(out, expect, rtol=1e-6)

    def test_zero_on_identical_rows(self):
        x = jnp.ones((4, 3), dtype=jnp.float32) * 7.5
        out = pairwise_sq_dists(x, x)
        np.testing.assert_allclose(out, np.zeros((4, 4)), atol=1e-4)

    def test_never_negative_under_cancellation(self):
        # Large coordinates provoke catastrophic cancellation.
        x = jnp.full((8, 4), 1e4, dtype=jnp.float32)
        out = pairwise_sq_dists(x, x + 1e-2)
        assert bool(jnp.all(out >= 0.0))

    def test_artifact_tile_geometry(self):
        # The exact shapes the AOT artifacts are compiled for.
        rng = np.random.default_rng(0)
        q = _rand(rng, (256, 8))
        r = _rand(rng, (1024, 8))
        out = pairwise_sq_dists(jnp.asarray(q), jnp.asarray(r))
        expect = pairwise_direct(jnp.asarray(q), jnp.asarray(r))
        np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


class TestHypothesisSweeps:
    @settings(max_examples=40, deadline=None)
    @given(
        nq=st.integers(1, 65),
        nr=st.integers(1, 130),
        d=st.integers(1, 12),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_direct_f32(self, nq, nr, d, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(_rand(rng, (nq, d)))
        r = jnp.asarray(_rand(rng, (nr, d)))
        out = pairwise_sq_dists(q, r)
        expect = pairwise_direct(q, r)
        np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)
        assert bool(jnp.all(out >= 0.0))

    @settings(max_examples=15, deadline=None)
    @given(
        nq=st.integers(2, 40),
        nr=st.integers(2, 40),
        d=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_bfloat16_loose(self, nq, nr, d, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(_rand(rng, (nq, d))).astype(jnp.bfloat16)
        r = jnp.asarray(_rand(rng, (nr, d))).astype(jnp.bfloat16)
        out = pairwise_sq_dists(q, r).astype(jnp.float32)
        expect = pairwise_direct(
            q.astype(jnp.float32), r.astype(jnp.float32)
        )
        np.testing.assert_allclose(out, expect, rtol=0.15, atol=0.5)

    @settings(max_examples=20, deadline=None)
    @given(
        tq=st.integers(1, 64),
        tr=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_tile_choice_never_changes_result(self, tq, tr, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(_rand(rng, (48, 5)))
        r = jnp.asarray(_rand(rng, (36, 5)))
        a = pairwise_sq_dists(q, r, tq=tq, tr=tr)
        b = pairwise_ref(q, r)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(extent=st.integers(1, 512), preferred=st.integers(1, 512))
    def test_pick_tile_is_divisor(self, extent, preferred):
        t = _pick_tile(extent, preferred)
        assert 1 <= t <= extent
        assert extent % t == 0
        assert t <= max(preferred, 1)


class TestSymmetryProperties:
    def test_symmetric_on_same_input(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(_rand(rng, (33, 4)))
        out = np.asarray(pairwise_sq_dists(x, x))
        np.testing.assert_allclose(out, out.T, rtol=1e-4, atol=1e-5)

    def test_transpose_equals_swapped_args(self):
        rng = np.random.default_rng(6)
        q = jnp.asarray(_rand(rng, (17, 6)))
        r = jnp.asarray(_rand(rng, (29, 6)))
        a = np.asarray(pairwise_sq_dists(q, r))
        b = np.asarray(pairwise_sq_dists(r, q))
        np.testing.assert_allclose(a, b.T, rtol=1e-4, atol=1e-5)

    def test_translation_invariance(self):
        rng = np.random.default_rng(7)
        q = jnp.asarray(_rand(rng, (10, 3)))
        r = jnp.asarray(_rand(rng, (12, 3)))
        shift = jnp.asarray([[1.5, -2.0, 0.25]])
        a = pairwise_sq_dists(q, r)
        b = pairwise_sq_dists(q + shift, r + shift)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


class TestPerfModel:
    def test_vmem_budget_default_tiles(self):
        # Default tile must fit VMEM (~16 MiB) with double buffering.
        assert vmem_bytes(128, 256, 8) * 2 < 16 * 1024 * 1024

    def test_mxu_estimate_monotone_in_tiles(self):
        small = mxu_utilization_estimate(8, 8, 8)
        big = mxu_utilization_estimate(128, 256, 8)
        assert 0.0 < small < big <= 1.0
