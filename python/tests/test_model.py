"""L2 correctness: knn_chunk and kmeans_assign vs numpy references."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import model
from compile.kernels.ref import kmeans_assign_ref, pairwise_direct

jax.config.update("jax_platform_name", "cpu")


def _np_knn(q, r, q_ids, r_ids, k):
    """Brute-force reference for knn_chunk."""
    d2 = np.asarray(pairwise_direct(jnp.asarray(q), jnp.asarray(r)))
    d2 = d2.astype(np.float64)
    invalid = (r_ids[None, :] == q_ids[:, None]) | (r_ids[None, :] < 0)
    d2[invalid] = np.inf
    out_d = np.full((q.shape[0], k), np.inf)
    out_i = np.full((q.shape[0], k), -1, dtype=np.int64)
    for i in range(q.shape[0]):
        order = np.argsort(d2[i], kind="stable")[:k]
        for s, j in enumerate(order):
            if np.isinf(d2[i][j]):
                break
            out_d[i, s] = d2[i][j]
            out_i[i, s] = r_ids[j]
    return out_d, out_i


class TestKnnChunk:
    def test_excludes_self_and_padding(self):
        rng = np.random.default_rng(1)
        q = rng.standard_normal((4, 3)).astype(np.float32)
        r = np.concatenate([q, rng.standard_normal((4, 3)).astype(np.float32)])
        q_ids = np.arange(4, dtype=np.int32)
        r_ids = np.concatenate([np.arange(4), [-1, 5, 6, 7]]).astype(np.int32)
        dists, ids = model.knn_chunk(
            jnp.asarray(q), jnp.asarray(r), jnp.asarray(q_ids), jnp.asarray(r_ids), k=3
        )
        ids = np.asarray(ids)
        for i in range(4):
            assert q_ids[i] not in ids[i], f"self id in row {i}: {ids[i]}"
            # r_ids[4] is padding (-1): index 4's *point* duplicates q rows,
            # so its id -1 must never be reported as a real neighbor with
            # finite distance... (-1 slots only where dist is masked).
        d = np.asarray(dists)
        assert ((ids >= 0) == (d < model.MASK_BIG / 2)).all()

    @settings(max_examples=25, deadline=None)
    @given(
        nq=st.integers(1, 20),
        nr=st.integers(2, 60),
        d=st.integers(1, 6),
        k=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_numpy_reference(self, nq, nr, d, k, seed):
        k = min(k, nr)  # lax.top_k requires k ≤ R
        rng = np.random.default_rng(seed)
        q = rng.standard_normal((nq, d)).astype(np.float32)
        r = rng.standard_normal((nr, d)).astype(np.float32)
        # Query ids overlap the reference id space; some refs padded.
        q_ids = rng.choice(max(nr * 2, nq), size=nq, replace=False).astype(np.int32)
        r_ids = np.arange(nr, dtype=np.int32)
        r_ids[rng.random(nr) < 0.2] = -1
        dists, ids = model.knn_chunk(
            jnp.asarray(q), jnp.asarray(r), jnp.asarray(q_ids), jnp.asarray(r_ids), k=k
        )
        ref_d, ref_i = _np_knn(q, r, q_ids, r_ids, k)
        got_d = np.asarray(dists, dtype=np.float64)
        got_d[got_d >= model.MASK_BIG / 2] = np.inf
        # Distances must match (ids can differ on exact ties).
        finite = np.isfinite(ref_d)
        np.testing.assert_allclose(got_d[finite], ref_d[finite], rtol=1e-3, atol=1e-3)
        assert (np.asarray(ids)[~finite] == -1).all()

    def test_sorted_ascending(self):
        rng = np.random.default_rng(2)
        q = rng.standard_normal((8, 4)).astype(np.float32)
        r = rng.standard_normal((32, 4)).astype(np.float32)
        dists, _ = model.knn_chunk(
            jnp.asarray(q),
            jnp.asarray(r),
            jnp.full((8,), -2, dtype=jnp.int32),
            jnp.arange(32, dtype=jnp.int32),
            k=5,
        )
        d = np.asarray(dists)
        assert (np.diff(d, axis=1) >= -1e-6).all()


class TestKmeansAssign:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(2, 60),
        k=st.integers(1, 10),
        d=st.integers(1, 6),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference(self, n, k, d, seed):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((n, d)).astype(np.float32)
        c = rng.standard_normal((k, d)).astype(np.float32)
        cmask = (rng.random(k) < 0.8).astype(np.float32)
        if cmask.sum() == 0:
            cmask[0] = 1.0
        pmask = (rng.random(n) < 0.9).astype(np.float32)
        got = model.kmeans_assign(
            jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask), jnp.asarray(pmask)
        )
        ref = kmeans_assign_ref(
            jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask), jnp.asarray(pmask)
        )
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
        np.testing.assert_allclose(got[1], ref[1], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got[2], ref[2], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got[3], ref[3], rtol=1e-3)

    def test_masked_centers_never_chosen(self):
        x = jnp.asarray(np.zeros((5, 2), dtype=np.float32))
        c = jnp.asarray(np.array([[100.0, 100.0], [0.1, 0.0]], dtype=np.float32))
        cmask = jnp.asarray(np.array([1.0, 0.0], dtype=np.float32))
        pmask = jnp.ones((5,), dtype=jnp.float32)
        assign, sums, counts, _ = model.kmeans_assign(x, c, cmask, pmask)
        # Center 1 is closer but masked → everything goes to center 0.
        assert (np.asarray(assign) == 0).all()
        assert counts[1] == 0.0

    def test_padded_points_excluded_from_stats(self):
        rng = np.random.default_rng(3)
        x = rng.standard_normal((10, 3)).astype(np.float32)
        c = rng.standard_normal((4, 3)).astype(np.float32)
        cmask = np.ones(4, dtype=np.float32)
        pmask = np.ones(10, dtype=np.float32)
        pmask[7:] = 0.0
        _, sums, counts, wcss = model.kmeans_assign(
            jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask), jnp.asarray(pmask)
        )
        assert float(np.asarray(counts).sum()) == 7.0
        # Recompute from the live prefix only.
        _, s2, c2, w2 = kmeans_assign_ref(
            jnp.asarray(x[:7]),
            jnp.asarray(c),
            jnp.asarray(cmask),
            jnp.ones(7, dtype=jnp.float32),
        )
        np.testing.assert_allclose(sums, s2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(wcss, w2, rtol=1e-4)

    def test_wcss_zero_when_points_on_centers(self):
        c = np.array([[0.0, 0.0], [5.0, 5.0]], dtype=np.float32)
        x = np.repeat(c, 3, axis=0)
        _, _, counts, wcss = model.kmeans_assign(
            jnp.asarray(x),
            jnp.asarray(c),
            jnp.ones(2, dtype=jnp.float32),
            jnp.ones(6, dtype=jnp.float32),
        )
        assert float(wcss) < 1e-5
        np.testing.assert_array_equal(np.asarray(counts), [3.0, 3.0])
