"""AOT pipeline checks: lowering emits parseable HLO text + a consistent
manifest, and the artifact geometry matches the constants shared with the
Rust runtime."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_knn_chunk_lowers_to_hlo_text(self):
        name, lowered, _ = aot.lower_knn_chunk()
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert f"q{aot.KNN_Q}_r{aot.KNN_R}" in name

    def test_kmeans_assign_lowers_to_hlo_text(self):
        name, lowered, _ = aot.lower_kmeans_assign()
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        # The root should be a tuple of 4 results (assign/sums/counts/wcss).
        assert "tuple(" in text.replace(" ", "")

    def test_lowered_executes_like_eager(self):
        # The lowered module, compiled and run through jax, must agree with
        # the eager function — this is the same computation the Rust side
        # executes via PJRT.
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.standard_normal((aot.KM_N, aot.DIM)).astype(np.float32))
        c = jnp.asarray(rng.standard_normal((aot.KM_K, aot.DIM)).astype(np.float32))
        cm = jnp.ones((aot.KM_K,), dtype=jnp.float32)
        pm = jnp.ones((aot.KM_N,), dtype=jnp.float32)
        compiled = jax.jit(model.kmeans_assign).lower(x, c, cm, pm).compile()
        got = compiled(x, c, cm, pm)
        ref = model.kmeans_assign(x, c, cm, pm)
        for g, r in zip(got, ref):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    def _manifest(self):
        with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_files_exist_and_nonempty(self):
        m = self._manifest()
        # One knn_chunk per neighbor-slot variant + one kmeans_assign.
        assert len(m["artifacts"]) == len(aot.KNN_KS) + 1
        for a in m["artifacts"]:
            path = os.path.join(ARTIFACT_DIR, a["file"])
            assert os.path.getsize(path) > 1000, a["file"]
            with open(path) as f:
                assert "HloModule" in f.read(200)

    def test_tile_geometry_consistent(self):
        m = self._manifest()
        t = m["tile"]
        assert t["knn_q"] == aot.KNN_Q
        assert t["knn_r"] == aot.KNN_R
        assert t["knn_k"] == aot.KNN_K
        assert t["km_n"] == aot.KM_N
        assert t["km_k"] == aot.KM_K
        assert t["dim"] == aot.DIM

    def test_signatures_match_tile(self):
        m = self._manifest()
        knns = [a for a in m["artifacts"] if a["name"].startswith("knn_chunk")]
        slot_counts = sorted(a["outputs"][0]["shape"][1] for a in knns)
        assert slot_counts == sorted(aot.KNN_KS)
        for knn in knns:
            assert knn["inputs"][0]["shape"] == [aot.KNN_Q, aot.DIM]
            assert knn["inputs"][1]["shape"] == [aot.KNN_R, aot.DIM]
            assert knn["outputs"][0]["shape"][0] == aot.KNN_Q
        km = next(a for a in m["artifacts"] if a["name"].startswith("kmeans_assign"))
        assert km["inputs"][0]["shape"] == [aot.KM_N, aot.DIM]
        assert km["outputs"][1]["shape"] == [aot.KM_K, aot.DIM]
